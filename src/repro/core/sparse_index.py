"""TPU-native sparse inner-product scoring (paper §2.2, §3.1–3.3; DESIGN.md §2).

Two cooperating structures, both built on the cache-sort permutation:

* ``TileSparseHead`` — the most-active ``d_head`` dimensions form an (N, d_head)
  block matrix.  After cache sorting, nonzeros cluster into contiguous row
  runs, so most (row-block × dim-block) VMEM tiles are entirely zero; the
  Pallas kernel (kernels/block_sparse.py) skips them.  This is the TPU
  re-derivation of the paper's cache-line argument: B datapoints per cache
  line → ``block_rows`` datapoints per VMEM tile.

* ``PaddedInvertedIndex`` — the power-law tail.  After eta-pruning each
  dimension holds at most ``L_max`` entries (paper §6.1.2 keeps "top 100s"),
  so the inverted lists pack into rectangular (d_active, L_max) row-id /
  value arrays: query scoring is a fixed-shape gather + scatter-add, the
  jit-able analogue of inverted-list accumulation.

Column ids are remapped to a compact per-shard space (only dimensions active
in the shard), which is what makes d^S = 1e9 feasible: a shard only ever
materializes its own active columns.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

__all__ = [
    "CompactColumns", "PaddedInvertedIndex", "TileSparseHead",
    "build_compact_columns", "build_padded_inverted_index",
    "build_tile_sparse_head", "score_inverted", "score_head_ref",
    "sparse_queries_to_padded", "PaddedSparseRows", "build_padded_rows",
    "score_rows", "DeltaPostings", "ValueForwardStream",
    "build_value_forward_stream",
]


# ---------------------------------------------------------------------------
# Compact column space
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompactColumns:
    """Mapping between global dimension ids and the shard's compact space."""
    global_ids: np.ndarray          # (d_active,) sorted global dim ids

    @property
    def num_active(self) -> int:
        return len(self.global_ids)

    def to_compact(self, global_dims: np.ndarray) -> np.ndarray:
        """Global dim ids -> compact ids; unknown dims -> num_active (sentinel)."""
        pos = np.searchsorted(self.global_ids, global_dims)
        pos = np.clip(pos, 0, len(self.global_ids) - 1)
        hit = self.global_ids[pos] == global_dims
        return np.where(hit, pos, self.num_active).astype(np.int32)


def build_compact_columns(x_sparse: sp.spmatrix) -> tuple[CompactColumns, sp.csr_matrix]:
    xc = x_sparse.tocsc()
    active = np.flatnonzero(np.diff(xc.indptr))
    cols = CompactColumns(global_ids=active)
    remapped = xc[:, active].tocsr()
    return cols, remapped


# ---------------------------------------------------------------------------
# Padded inverted index (tail path)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedInvertedIndex:
    rows: jax.Array      # (d_active, L_max) int32, pad = num_points (dropped)
    vals: jax.Array      # (d_active, L_max) float32, pad = 0
    num_points: int = dataclasses.field(metadata=dict(static=True))


def build_padded_inverted_index(x_compact: sp.csr_matrix,
                                l_max: int | None = None) -> PaddedInvertedIndex:
    """x_compact: CSR with compact columns (from build_compact_columns),
    already pruned so each column has <= a few hundred entries."""
    xc = x_compact.tocsc()
    n, d = xc.shape
    lens = np.diff(xc.indptr)
    if l_max is None:
        l_max = max(int(lens.max(initial=1)), 1)
    rows = np.full((d, l_max), n, dtype=np.int32)
    vals = np.zeros((d, l_max), dtype=np.float32)
    for j in range(d):
        lo, hi = xc.indptr[j], xc.indptr[j + 1]
        m = min(hi - lo, l_max)
        if m < hi - lo:
            # keep the largest-magnitude entries if over capacity
            order = np.argsort(-np.abs(xc.data[lo:hi]))[:m]
            rows[j, :m] = xc.indices[lo:hi][order]
            vals[j, :m] = xc.data[lo:hi][order]
        else:
            rows[j, :m] = xc.indices[lo:hi]
            vals[j, :m] = xc.data[lo:hi]
    return PaddedInvertedIndex(rows=jnp.asarray(rows), vals=jnp.asarray(vals),
                               num_points=n)


def sparse_queries_to_padded(q_sparse: sp.spmatrix, cols: CompactColumns,
                             nq_max: int = 512) -> tuple[np.ndarray, np.ndarray]:
    """(Q, nq_max) compact dim ids (pad = d_active) + values (pad = 0)."""
    qr = q_sparse.tocsr()
    q = qr.shape[0]
    dims = np.full((q, nq_max), cols.num_active, dtype=np.int32)
    vals = np.zeros((q, nq_max), dtype=np.float32)
    for i in range(q):
        lo, hi = qr.indptr[i], qr.indptr[i + 1]
        compact = cols.to_compact(qr.indices[lo:hi])
        keep = compact < cols.num_active
        c, v = compact[keep], qr.data[lo:hi][keep]
        if len(c) > nq_max:                      # keep largest |q_j| on overflow
            order = np.argsort(-np.abs(v))[:nq_max]
            c, v = c[order], v[order]
        dims[i, : len(c)] = c
        vals[i, : len(c)] = v
    return dims, vals


class DeltaPostings:
    """Append-only inverted index for a delta shard (DESIGN.md §6).

    Host-side mirror of ``PaddedInvertedIndex`` over the FROZEN compact
    column space of the serving main index: inserting a row appends one
    posting per nonzero dim.  ``l_max`` (the rectangle width) doubles
    amortized when a dim's list overflows — until ``l_cap``, the delta's
    analogue of the main index's eta-pruning: a power-law hot dim would
    otherwise grow its list to the full delta row count and blow up the
    pass-1 gather rectangle.  Beyond the cap, ``append`` hands the entries
    back as SPILL and the delta shard stores them in its per-slot residual
    rows instead (scored exactly in pass 3) — the paper's data-index /
    residual-index split applied to the streaming tier.  Tombstoned rows
    keep their postings; the delta's ``valid_mask`` zeroes their scores, and
    compaction drops them for real.
    """

    def __init__(self, d_active: int, l_max: int = 4,
                 l_cap: int | None = 16):
        self.d_active = int(d_active)
        self.l_max = max(int(l_max), 1)
        self.l_cap = None if l_cap is None else max(int(l_cap), self.l_max)
        self._rows = np.full((self.d_active, self.l_max), -1, np.int32)
        self._vals = np.zeros((self.d_active, self.l_max), np.float32)
        self._lens = np.zeros(self.d_active, np.int32)

    def append(self, slot: int, dims: np.ndarray,
               vals: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Add row ``slot``'s postings; dims are compact ids < d_active.
        Returns ``(spill_dims, spill_vals)``: the entries whose dim list is
        at ``l_cap`` — the caller owns scoring those through pass 3."""
        spill_d, spill_v = [], []
        for d, v in zip(np.asarray(dims, np.int64), np.asarray(vals)):
            n = int(self._lens[d])
            if self.l_cap is not None and n >= self.l_cap:
                spill_d.append(int(d))
                spill_v.append(float(v))
                continue
            if n == self.l_max:
                grow = self.l_max
                self._rows = np.pad(self._rows, ((0, 0), (0, grow)),
                                    constant_values=-1)
                self._vals = np.pad(self._vals, ((0, 0), (0, grow)))
                self.l_max *= 2
            self._rows[d, n] = slot
            self._vals[d, n] = v
            self._lens[d] = n + 1
        return (np.asarray(spill_d, np.int32),
                np.asarray(spill_v, np.float32))

    def to_padded(self, num_points: int) -> PaddedInvertedIndex:
        """Materialize for the device: empty slots get the ``num_points``
        sentinel (scatter-dropped by score_inverted), exactly like the batch
        builder's padding."""
        rows = np.where(self._rows >= 0, self._rows,
                        num_points).astype(np.int32)
        return PaddedInvertedIndex(rows=jnp.asarray(rows),
                                   vals=jnp.asarray(self._vals),
                                   num_points=num_points)

    def rows_for(self, dims: np.ndarray,
                 num_points: int) -> tuple[np.ndarray, np.ndarray]:
        """Padded ``(rows, vals)`` rectangles for just the given dims — the
        incremental device-update unit (DESIGN.md §6.1): after an insert,
        only the touched dims' posting rows cross to the device instead of
        the whole (d_active, l_max) rectangle."""
        d = np.asarray(dims, np.int64)
        rows = np.where(self._rows[d] >= 0, self._rows[d],
                        num_points).astype(np.int32)
        return rows, self._vals[d]


@jax.jit
def score_inverted(index: PaddedInvertedIndex, q_dims: jax.Array,
                   q_vals: jax.Array) -> jax.Array:
    """Inverted-index accumulation (paper §2.2) as gather + scatter-add.

    q_dims/q_vals: (Q, nq) compact ids / values.  Returns (Q, N) scores.
    """
    qn, nq = q_dims.shape
    n = index.num_points
    rows_g = jnp.take(index.rows, q_dims, axis=0, mode="fill",
                      fill_value=n)                               # (Q, nq, L)
    vals_g = jnp.take(index.vals, q_dims, axis=0, mode="fill",
                      fill_value=0.0)                             # (Q, nq, L)
    contrib = vals_g * q_vals[:, :, None]
    acc = jnp.zeros((qn, n), jnp.float32)
    qidx = jnp.arange(qn)[:, None, None]
    acc = acc.at[
        jnp.broadcast_to(qidx, rows_g.shape), rows_g
    ].add(contrib, mode="drop")
    return acc


# ---------------------------------------------------------------------------
# Tile-sorted head block (cache-sorting payoff path)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TileSparseHead:
    """Dense (N, d_head) block of the most-active dims + tile occupancy."""
    block: jax.Array        # (N_pad, d_head) float32 (or bf16), cache-sorted rows
    occupancy: jax.Array    # (N_pad/block_rows, d_head/block_cols) bool
    head_dims: jax.Array    # (d_head,) compact column ids covered by the block
    block_rows: int = dataclasses.field(metadata=dict(static=True))
    block_cols: int = dataclasses.field(metadata=dict(static=True))


def build_tile_sparse_head(x_compact: sp.csr_matrix, head_dims: np.ndarray,
                           block_rows: int = 128, block_cols: int = 128,
                           dtype=jnp.float32) -> TileSparseHead:
    """head_dims: compact column ids (most active).  Rows are assumed already
    permuted by cache_sort (apply pi before calling)."""
    n = x_compact.shape[0]
    d_head = len(head_dims)
    d_head_pad = -(-d_head // block_cols) * block_cols
    n_pad = -(-n // block_rows) * block_rows
    sub = x_compact[:, head_dims].toarray().astype(np.float32)
    block = np.zeros((n_pad, d_head_pad), np.float32)
    block[:n, :d_head] = sub
    occ = (
        block.reshape(n_pad // block_rows, block_rows,
                      d_head_pad // block_cols, block_cols)
        .any(axis=(1, 3))
    )
    dims = np.full(d_head_pad, -1, np.int32)
    dims[:d_head] = head_dims
    return TileSparseHead(block=jnp.asarray(block, dtype),
                          occupancy=jnp.asarray(occ),
                          head_dims=jnp.asarray(dims),
                          block_rows=block_rows, block_cols=block_cols)


@jax.jit
def score_head_ref(head: TileSparseHead, q_head: jax.Array) -> jax.Array:
    """Oracle head scoring: (Q, d_head_pad) @ block^T -> (Q, N_pad).

    The Pallas kernel (kernels/block_sparse.py) must match this while skipping
    occupancy-0 tiles."""
    return q_head.astype(jnp.float32) @ head.block.astype(jnp.float32).T


def queries_head_dense(q_dims: np.ndarray, q_vals: np.ndarray,
                       head_dims: np.ndarray, d_head_pad: int) -> np.ndarray:
    """Scatter padded sparse queries into the dense head subspace.

    q_dims/q_vals: (Q, nq) compact ids/values; head_dims: (d_head_pad,) compact
    ids (pad = -1).  Returns (Q, d_head_pad) float32."""
    lookup = {int(c): i for i, c in enumerate(head_dims) if c >= 0}
    qn, nq = q_dims.shape
    out = np.zeros((qn, d_head_pad), np.float32)
    for i in range(qn):
        for s in range(nq):
            c = int(q_dims[i, s])
            pos = lookup.get(c)
            if pos is not None:
                out[i, pos] += q_vals[i, s]
    return out


# ---------------------------------------------------------------------------
# Padded row storage — residual reordering needs per-candidate sparse rows
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedSparseRows:
    cols: jax.Array    # (N, R_max) int32 compact col ids, pad = d_active
    vals: jax.Array    # (N, R_max) float32, pad = 0


def build_padded_rows(x_compact: sp.csr_matrix,
                      r_max: int | None = None) -> PaddedSparseRows:
    xr = x_compact.tocsr()
    n, d = xr.shape
    lens = np.diff(xr.indptr)
    if r_max is None:
        r_max = max(int(lens.max(initial=1)), 1)
    cols = np.full((n, r_max), d, dtype=np.int32)
    vals = np.zeros((n, r_max), dtype=np.float32)
    for i in range(n):
        lo, hi = xr.indptr[i], xr.indptr[i + 1]
        m = min(hi - lo, r_max)
        if m < hi - lo:
            order = np.argsort(-np.abs(xr.data[lo:hi]))[:m]
            cols[i, :m] = xr.indices[lo:hi][order]
            vals[i, :m] = xr.data[lo:hi][order]
        else:
            cols[i, :m] = xr.indices[lo:hi]
            vals[i, :m] = xr.data[lo:hi]
    return PaddedSparseRows(cols=jnp.asarray(cols), vals=jnp.asarray(vals))


@jax.jit
def score_rows(rows: PaddedSparseRows, candidates: jax.Array,
               q_dense_cols: jax.Array) -> jax.Array:
    """Exact sparse dot for selected rows (residual reorder pass 3).

    candidates: (Q, C) row ids; q_dense_cols: (Q, d_active + 1) query scattered
    into the compact column space with one trailing zero pad slot.
    Returns (Q, C) partial inner products."""
    cand_cols = jnp.take(rows.cols, candidates, axis=0, mode="clip")  # (Q,C,R)
    cand_vals = jnp.take(rows.vals, candidates, axis=0, mode="clip")
    qv = jnp.take_along_axis(
        q_dense_cols[:, None, :], cand_cols.astype(jnp.int32), axis=2
    )                                                                 # (Q,C,R)
    return jnp.sum(cand_vals * qv, axis=-1)


# ---------------------------------------------------------------------------
# Value-forward stream (SINDI-motivated sparse pass-1; DESIGN.md §2.5)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ValueForwardStream:
    """Host-planned posting stream for the value-forward Pallas kernel.

    Instead of the (Q, nq, L_max) gather rectangle + (Q, N) scatter-add of
    ``score_inverted``, the query's postings are flattened into one
    row-sorted (row, query, contribution) stream per (query-block,
    row-block) pair — SINDI's value-forward traversal: multiply q_j into the
    posting values once at plan time, then the kernel only accumulates.

    ``ptr`` is in CHUNK units (not entries): each (query-block, row-block)
    segment is padded to a multiple of ``chunk`` so Pallas BlockSpec index
    maps — which address whole blocks — can stream exactly the chunks a
    tile owns via scalar prefetch.
    """
    ptr: jax.Array        # (QB*(NB+1),) int32 chunk offsets, CSR per q-block
    rows: jax.Array       # (QB, P_pad) int32 block-LOCAL row ids, pad = bn
    qidx: jax.Array       # (QB, P_pad) int32 query index within block, pad 0
    contrib: jax.Array    # (QB, P_pad) float32 q_val * posting_val, pad 0
    num_points: int
    num_queries: int
    bq: int
    bn: int
    chunk: int
    max_steps: int
    num_row_blocks: int


def build_value_forward_stream(index: PaddedInvertedIndex, q_dims: np.ndarray,
                               q_vals: np.ndarray, *, bq: int = 8,
                               bn: int = 512,
                               chunk: int = 128) -> ValueForwardStream:
    """Plan the value-forward stream on the host (numpy; not jittable —
    stream length depends on the query nonzero pattern, which is exactly why
    this lives outside the jitted three-pass and is exposed as the
    standalone ``kernels.ops.score_inverted_vf``)."""
    rows_idx = np.asarray(index.rows)
    vals_idx = np.asarray(index.vals)
    d_active = rows_idx.shape[0]
    n = index.num_points
    q_dims = np.asarray(q_dims)
    q_vals = np.asarray(q_vals)
    qn = q_dims.shape[0]

    n_pad = max(-(-n // bn) * bn, bn)
    nb = n_pad // bn
    qb = max(-(-qn // bq), 1)

    per_block: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    ptr = np.zeros(qb * (nb + 1), np.int32)
    max_steps = 1
    for b in range(qb):
        lo, hi = b * bq, min((b + 1) * bq, qn)
        ent_r: list[np.ndarray] = []
        ent_q: list[np.ndarray] = []
        ent_c: list[np.ndarray] = []
        for i in range(lo, hi):
            dims = q_dims[i]
            keep = dims < d_active
            dims = dims[keep].astype(np.int64)
            qv = q_vals[i][keep]
            if dims.size == 0:
                continue
            r = rows_idx[dims]                              # (nq_i, L_max)
            v = vals_idx[dims]
            live = r < n                                    # drop pad sentinel
            ent_r.append(r[live])
            ent_q.append(np.full(int(live.sum()), i - lo, np.int32))
            ent_c.append((qv[:, None] * v)[live])
        if ent_r:
            r_all = np.concatenate(ent_r)
            q_all = np.concatenate(ent_q)
            c_all = np.concatenate(ent_c).astype(np.float32)
        else:
            r_all = np.zeros(0, np.int64)
            q_all = np.zeros(0, np.int32)
            c_all = np.zeros(0, np.float32)
        order = np.argsort(r_all, kind="stable")
        r_all, q_all, c_all = r_all[order], q_all[order], c_all[order]

        seg_r: list[np.ndarray] = []
        seg_q: list[np.ndarray] = []
        seg_c: list[np.ndarray] = []
        bounds = np.searchsorted(r_all, np.arange(nb + 1) * bn)
        off = 0
        for j in range(nb):
            s0, s1 = int(bounds[j]), int(bounds[j + 1])
            m = s1 - s0
            m_pad = -(-max(m, 0) // chunk) * chunk
            ptr[b * (nb + 1) + j] = off
            if m_pad:
                lr = np.full(m_pad, bn, np.int32)            # pad: no row match
                lq = np.zeros(m_pad, np.int32)
                lc = np.zeros(m_pad, np.float32)
                lr[:m] = r_all[s0:s1] - j * bn               # block-LOCAL ids
                lq[:m] = q_all[s0:s1]
                lc[:m] = c_all[s0:s1]
                seg_r.append(lr)
                seg_q.append(lq)
                seg_c.append(lc)
            off += m_pad // chunk
            max_steps = max(max_steps, m_pad // chunk)
        ptr[b * (nb + 1) + nb] = off
        if seg_r:
            per_block.append((np.concatenate(seg_r), np.concatenate(seg_q),
                              np.concatenate(seg_c)))
        else:
            per_block.append((np.full(chunk, bn, np.int32),
                              np.zeros(chunk, np.int32),
                              np.zeros(chunk, np.float32)))

    p_pad = max(max(pb[0].size for pb in per_block), chunk)
    rows_out = np.full((qb, p_pad), bn, np.int32)
    qidx_out = np.zeros((qb, p_pad), np.int32)
    contrib_out = np.zeros((qb, p_pad), np.float32)
    for b, (pr, pq, pc) in enumerate(per_block):
        rows_out[b, :pr.size] = pr
        qidx_out[b, :pq.size] = pq
        contrib_out[b, :pc.size] = pc

    return ValueForwardStream(
        ptr=jnp.asarray(ptr), rows=jnp.asarray(rows_out),
        qidx=jnp.asarray(qidx_out), contrib=jnp.asarray(contrib_out),
        num_points=n, num_queries=qn, bq=bq, bn=bn, chunk=chunk,
        max_steps=max_steps, num_row_blocks=nb)
