"""TPU-native sparse inner-product scoring (paper §2.2, §3.1–3.3; DESIGN.md §2).

Two cooperating structures, both built on the cache-sort permutation:

* ``TileSparseHead`` — the most-active ``d_head`` dimensions form an (N, d_head)
  block matrix.  After cache sorting, nonzeros cluster into contiguous row
  runs, so most (row-block × dim-block) VMEM tiles are entirely zero; the
  Pallas kernel (kernels/block_sparse.py) skips them.  This is the TPU
  re-derivation of the paper's cache-line argument: B datapoints per cache
  line → ``block_rows`` datapoints per VMEM tile.

* ``PaddedInvertedIndex`` — the power-law tail.  After eta-pruning each
  dimension holds at most ``L_max`` entries (paper §6.1.2 keeps "top 100s"),
  so the inverted lists pack into rectangular (d_active, L_max) row-id /
  value arrays: query scoring is a fixed-shape gather + scatter-add, the
  jit-able analogue of inverted-list accumulation.

Column ids are remapped to a compact per-shard space (only dimensions active
in the shard), which is what makes d^S = 1e9 feasible: a shard only ever
materializes its own active columns.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

__all__ = [
    "CompactColumns", "PaddedInvertedIndex", "TileSparseHead",
    "build_compact_columns", "build_padded_inverted_index",
    "build_tile_sparse_head", "score_inverted", "score_head_ref",
    "sparse_queries_to_padded", "PaddedSparseRows", "build_padded_rows",
    "score_rows", "DeltaPostings",
]


# ---------------------------------------------------------------------------
# Compact column space
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompactColumns:
    """Mapping between global dimension ids and the shard's compact space."""
    global_ids: np.ndarray          # (d_active,) sorted global dim ids

    @property
    def num_active(self) -> int:
        return len(self.global_ids)

    def to_compact(self, global_dims: np.ndarray) -> np.ndarray:
        """Global dim ids -> compact ids; unknown dims -> num_active (sentinel)."""
        pos = np.searchsorted(self.global_ids, global_dims)
        pos = np.clip(pos, 0, len(self.global_ids) - 1)
        hit = self.global_ids[pos] == global_dims
        return np.where(hit, pos, self.num_active).astype(np.int32)


def build_compact_columns(x_sparse: sp.spmatrix) -> tuple[CompactColumns, sp.csr_matrix]:
    xc = x_sparse.tocsc()
    active = np.flatnonzero(np.diff(xc.indptr))
    cols = CompactColumns(global_ids=active)
    remapped = xc[:, active].tocsr()
    return cols, remapped


# ---------------------------------------------------------------------------
# Padded inverted index (tail path)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedInvertedIndex:
    rows: jax.Array      # (d_active, L_max) int32, pad = num_points (dropped)
    vals: jax.Array      # (d_active, L_max) float32, pad = 0
    num_points: int = dataclasses.field(metadata=dict(static=True))


def build_padded_inverted_index(x_compact: sp.csr_matrix,
                                l_max: int | None = None) -> PaddedInvertedIndex:
    """x_compact: CSR with compact columns (from build_compact_columns),
    already pruned so each column has <= a few hundred entries."""
    xc = x_compact.tocsc()
    n, d = xc.shape
    lens = np.diff(xc.indptr)
    if l_max is None:
        l_max = max(int(lens.max(initial=1)), 1)
    rows = np.full((d, l_max), n, dtype=np.int32)
    vals = np.zeros((d, l_max), dtype=np.float32)
    for j in range(d):
        lo, hi = xc.indptr[j], xc.indptr[j + 1]
        m = min(hi - lo, l_max)
        if m < hi - lo:
            # keep the largest-magnitude entries if over capacity
            order = np.argsort(-np.abs(xc.data[lo:hi]))[:m]
            rows[j, :m] = xc.indices[lo:hi][order]
            vals[j, :m] = xc.data[lo:hi][order]
        else:
            rows[j, :m] = xc.indices[lo:hi]
            vals[j, :m] = xc.data[lo:hi]
    return PaddedInvertedIndex(rows=jnp.asarray(rows), vals=jnp.asarray(vals),
                               num_points=n)


def sparse_queries_to_padded(q_sparse: sp.spmatrix, cols: CompactColumns,
                             nq_max: int = 512) -> tuple[np.ndarray, np.ndarray]:
    """(Q, nq_max) compact dim ids (pad = d_active) + values (pad = 0)."""
    qr = q_sparse.tocsr()
    q = qr.shape[0]
    dims = np.full((q, nq_max), cols.num_active, dtype=np.int32)
    vals = np.zeros((q, nq_max), dtype=np.float32)
    for i in range(q):
        lo, hi = qr.indptr[i], qr.indptr[i + 1]
        compact = cols.to_compact(qr.indices[lo:hi])
        keep = compact < cols.num_active
        c, v = compact[keep], qr.data[lo:hi][keep]
        if len(c) > nq_max:                      # keep largest |q_j| on overflow
            order = np.argsort(-np.abs(v))[:nq_max]
            c, v = c[order], v[order]
        dims[i, : len(c)] = c
        vals[i, : len(c)] = v
    return dims, vals


class DeltaPostings:
    """Append-only inverted index for a delta shard (DESIGN.md §6).

    Host-side mirror of ``PaddedInvertedIndex`` over the FROZEN compact
    column space of the serving main index: inserting a row appends one
    posting per nonzero dim.  ``l_max`` (the rectangle width) doubles
    amortized when a dim's list overflows — until ``l_cap``, the delta's
    analogue of the main index's eta-pruning: a power-law hot dim would
    otherwise grow its list to the full delta row count and blow up the
    pass-1 gather rectangle.  Beyond the cap, ``append`` hands the entries
    back as SPILL and the delta shard stores them in its per-slot residual
    rows instead (scored exactly in pass 3) — the paper's data-index /
    residual-index split applied to the streaming tier.  Tombstoned rows
    keep their postings; the delta's ``valid_mask`` zeroes their scores, and
    compaction drops them for real.
    """

    def __init__(self, d_active: int, l_max: int = 4,
                 l_cap: int | None = 16):
        self.d_active = int(d_active)
        self.l_max = max(int(l_max), 1)
        self.l_cap = None if l_cap is None else max(int(l_cap), self.l_max)
        self._rows = np.full((self.d_active, self.l_max), -1, np.int32)
        self._vals = np.zeros((self.d_active, self.l_max), np.float32)
        self._lens = np.zeros(self.d_active, np.int32)

    def append(self, slot: int, dims: np.ndarray,
               vals: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Add row ``slot``'s postings; dims are compact ids < d_active.
        Returns ``(spill_dims, spill_vals)``: the entries whose dim list is
        at ``l_cap`` — the caller owns scoring those through pass 3."""
        spill_d, spill_v = [], []
        for d, v in zip(np.asarray(dims, np.int64), np.asarray(vals)):
            n = int(self._lens[d])
            if self.l_cap is not None and n >= self.l_cap:
                spill_d.append(int(d))
                spill_v.append(float(v))
                continue
            if n == self.l_max:
                grow = self.l_max
                self._rows = np.pad(self._rows, ((0, 0), (0, grow)),
                                    constant_values=-1)
                self._vals = np.pad(self._vals, ((0, 0), (0, grow)))
                self.l_max *= 2
            self._rows[d, n] = slot
            self._vals[d, n] = v
            self._lens[d] = n + 1
        return (np.asarray(spill_d, np.int32),
                np.asarray(spill_v, np.float32))

    def to_padded(self, num_points: int) -> PaddedInvertedIndex:
        """Materialize for the device: empty slots get the ``num_points``
        sentinel (scatter-dropped by score_inverted), exactly like the batch
        builder's padding."""
        rows = np.where(self._rows >= 0, self._rows,
                        num_points).astype(np.int32)
        return PaddedInvertedIndex(rows=jnp.asarray(rows),
                                   vals=jnp.asarray(self._vals),
                                   num_points=num_points)

    def rows_for(self, dims: np.ndarray,
                 num_points: int) -> tuple[np.ndarray, np.ndarray]:
        """Padded ``(rows, vals)`` rectangles for just the given dims — the
        incremental device-update unit (DESIGN.md §6.1): after an insert,
        only the touched dims' posting rows cross to the device instead of
        the whole (d_active, l_max) rectangle."""
        d = np.asarray(dims, np.int64)
        rows = np.where(self._rows[d] >= 0, self._rows[d],
                        num_points).astype(np.int32)
        return rows, self._vals[d]


@jax.jit
def score_inverted(index: PaddedInvertedIndex, q_dims: jax.Array,
                   q_vals: jax.Array) -> jax.Array:
    """Inverted-index accumulation (paper §2.2) as gather + scatter-add.

    q_dims/q_vals: (Q, nq) compact ids / values.  Returns (Q, N) scores.
    """
    qn, nq = q_dims.shape
    n = index.num_points
    rows_g = jnp.take(index.rows, q_dims, axis=0, mode="fill",
                      fill_value=n)                               # (Q, nq, L)
    vals_g = jnp.take(index.vals, q_dims, axis=0, mode="fill",
                      fill_value=0.0)                             # (Q, nq, L)
    contrib = vals_g * q_vals[:, :, None]
    acc = jnp.zeros((qn, n), jnp.float32)
    qidx = jnp.arange(qn)[:, None, None]
    acc = acc.at[
        jnp.broadcast_to(qidx, rows_g.shape), rows_g
    ].add(contrib, mode="drop")
    return acc


# ---------------------------------------------------------------------------
# Tile-sorted head block (cache-sorting payoff path)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TileSparseHead:
    """Dense (N, d_head) block of the most-active dims + tile occupancy."""
    block: jax.Array        # (N_pad, d_head) float32 (or bf16), cache-sorted rows
    occupancy: jax.Array    # (N_pad/block_rows, d_head/block_cols) bool
    head_dims: jax.Array    # (d_head,) compact column ids covered by the block
    block_rows: int = dataclasses.field(metadata=dict(static=True))
    block_cols: int = dataclasses.field(metadata=dict(static=True))


def build_tile_sparse_head(x_compact: sp.csr_matrix, head_dims: np.ndarray,
                           block_rows: int = 128, block_cols: int = 128,
                           dtype=jnp.float32) -> TileSparseHead:
    """head_dims: compact column ids (most active).  Rows are assumed already
    permuted by cache_sort (apply pi before calling)."""
    n = x_compact.shape[0]
    d_head = len(head_dims)
    d_head_pad = -(-d_head // block_cols) * block_cols
    n_pad = -(-n // block_rows) * block_rows
    sub = x_compact[:, head_dims].toarray().astype(np.float32)
    block = np.zeros((n_pad, d_head_pad), np.float32)
    block[:n, :d_head] = sub
    occ = (
        block.reshape(n_pad // block_rows, block_rows,
                      d_head_pad // block_cols, block_cols)
        .any(axis=(1, 3))
    )
    dims = np.full(d_head_pad, -1, np.int32)
    dims[:d_head] = head_dims
    return TileSparseHead(block=jnp.asarray(block, dtype),
                          occupancy=jnp.asarray(occ),
                          head_dims=jnp.asarray(dims),
                          block_rows=block_rows, block_cols=block_cols)


@jax.jit
def score_head_ref(head: TileSparseHead, q_head: jax.Array) -> jax.Array:
    """Oracle head scoring: (Q, d_head_pad) @ block^T -> (Q, N_pad).

    The Pallas kernel (kernels/block_sparse.py) must match this while skipping
    occupancy-0 tiles."""
    return q_head.astype(jnp.float32) @ head.block.astype(jnp.float32).T


def queries_head_dense(q_dims: np.ndarray, q_vals: np.ndarray,
                       head_dims: np.ndarray, d_head_pad: int) -> np.ndarray:
    """Scatter padded sparse queries into the dense head subspace.

    q_dims/q_vals: (Q, nq) compact ids/values; head_dims: (d_head_pad,) compact
    ids (pad = -1).  Returns (Q, d_head_pad) float32."""
    lookup = {int(c): i for i, c in enumerate(head_dims) if c >= 0}
    qn, nq = q_dims.shape
    out = np.zeros((qn, d_head_pad), np.float32)
    for i in range(qn):
        for s in range(nq):
            c = int(q_dims[i, s])
            pos = lookup.get(c)
            if pos is not None:
                out[i, pos] += q_vals[i, s]
    return out


# ---------------------------------------------------------------------------
# Padded row storage — residual reordering needs per-candidate sparse rows
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PaddedSparseRows:
    cols: jax.Array    # (N, R_max) int32 compact col ids, pad = d_active
    vals: jax.Array    # (N, R_max) float32, pad = 0


def build_padded_rows(x_compact: sp.csr_matrix,
                      r_max: int | None = None) -> PaddedSparseRows:
    xr = x_compact.tocsr()
    n, d = xr.shape
    lens = np.diff(xr.indptr)
    if r_max is None:
        r_max = max(int(lens.max(initial=1)), 1)
    cols = np.full((n, r_max), d, dtype=np.int32)
    vals = np.zeros((n, r_max), dtype=np.float32)
    for i in range(n):
        lo, hi = xr.indptr[i], xr.indptr[i + 1]
        m = min(hi - lo, r_max)
        if m < hi - lo:
            order = np.argsort(-np.abs(xr.data[lo:hi]))[:m]
            cols[i, :m] = xr.indices[lo:hi][order]
            vals[i, :m] = xr.data[lo:hi][order]
        else:
            cols[i, :m] = xr.indices[lo:hi]
            vals[i, :m] = xr.data[lo:hi]
    return PaddedSparseRows(cols=jnp.asarray(cols), vals=jnp.asarray(vals))


@jax.jit
def score_rows(rows: PaddedSparseRows, candidates: jax.Array,
               q_dense_cols: jax.Array) -> jax.Array:
    """Exact sparse dot for selected rows (residual reorder pass 3).

    candidates: (Q, C) row ids; q_dense_cols: (Q, d_active + 1) query scattered
    into the compact column space with one trailing zero pad slot.
    Returns (Q, C) partial inner products."""
    cand_cols = jnp.take(rows.cols, candidates, axis=0, mode="clip")  # (Q,C,R)
    cand_vals = jnp.take(rows.vals, candidates, axis=0, mode="clip")
    qv = jnp.take_along_axis(
        q_dense_cols[:, None, :], cand_cols.astype(jnp.int32), axis=2
    )                                                                 # (Q,C,R)
    return jnp.sum(cand_vals * qv, axis=-1)
