"""Core library: hybrid sparse-dense inner product approximation.

Public API re-exports; see DESIGN.md for the paper <-> module map.
NOTE: the Algorithm-1 entry point lives at repro.core.cache_sort.cache_sort
(not re-exported here: it would shadow the submodule attribute).
"""

from . import cache_sort                                              # noqa: F401
from .cache_sort import (expected_cost_unsorted,                      # noqa: F401
                         expected_cost_sorted_bound, measured_block_cost,
                         block_occupancy, power_law_probs)
from .hybrid import HybridIndex, HybridIndexParams, SearchResult      # noqa: F401
from .pq import (PQCodebooks, train_codebooks, pq_encode, pq_decode,  # noqa: F401
                 adc_lut, adc_scores_ref, scalar_quantize, ScalarQuant)
from .pruning import prune_split, per_dim_thresholds                  # noqa: F401
from .streaming import DeltaShard, MutableState, search_mutable       # noqa: F401
