"""Residual reordering passes (paper §5).

q·x = q·x̃ + q·(x - x̃): first-pass approximate scores from the lossy data
indices are refined for a small overfetched candidate set by adding
query·residual terms, restoring (near-)exact inner products at O(h) cost.

Pass 1  overfetch alpha*h from sparse+dense data indices (done in hybrid.py)
Pass 2  add dense residual (int8 scalar-quantized, K_V=d^D, l=256), keep beta*h
Pass 3  add sparse residual (eps-pruned rows), return top h
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .pq import ScalarQuant
from .sparse_index import PaddedSparseRows, score_rows

__all__ = ["topk_candidates", "dense_residual_scores", "reorder_pass"]


@partial(jax.jit, static_argnums=(1,))
def topk_candidates(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """(Q, N) -> ((Q, k) scores, (Q, k) ids)."""
    return jax.lax.top_k(scores, k)


@jax.jit
def dense_residual_scores(sq: ScalarQuant, candidates: jax.Array,
                          q_dense: jax.Array) -> jax.Array:
    """q^D · residual[cand] with int8 rows dequantized on the fly.

    candidates: (Q, C); q_dense: (Q, d^D).  Returns (Q, C).

    The affine dequantization is folded into the dot:
      q·(s*(r+128)+z) = (q*s)·r + 128*(q·s) + q·z
    so the gathered int8 rows are contracted directly (this is also what the
    TPU path does — int8 rows stream from HBM, VPU multiply-accumulate).
    """
    rows = jnp.take(sq.q, candidates, axis=0, mode="clip")        # (Q, C, d) int8
    qs = q_dense * sq.scale[None, :]                              # (Q, d)
    base = 128.0 * jnp.sum(qs, axis=-1) + q_dense @ sq.zero       # (Q,)
    dot = jnp.einsum("qcd,qd->qc", rows.astype(jnp.float32), qs)
    return dot + base[:, None]


@partial(jax.jit, static_argnums=(3,))
def reorder_pass(prev_scores: jax.Array, prev_ids: jax.Array,
                 extra_scores: jax.Array, keep: int):
    """Refine candidate scores with a residual term and shrink the set.

    prev_scores/prev_ids: (Q, C); extra_scores: (Q, C) residual contribution.
    Returns ((Q, keep) scores, (Q, keep) ids)."""
    refined = prev_scores + extra_scores
    vals, pos = jax.lax.top_k(refined, keep)
    ids = jnp.take_along_axis(prev_ids, pos, axis=1)
    return vals, ids


@jax.jit
def sparse_residual_scores(rows: PaddedSparseRows, candidates: jax.Array,
                           q_cols_dense: jax.Array) -> jax.Array:
    """Wrapper so hybrid.py imports every pass from one module."""
    return score_rows(rows, candidates, q_cols_dense)
