"""The paper's §7.2 baselines, implemented in full.

Every baseline returns (ids, scores) of shape (Q, h) plus wall time, so the
benchmark harness (benchmarks/table2.py, table3.py) can reproduce the paper's
tables directly.

  * dense_brute_force          — sparse padded to dense, full matmul
  * sparse_brute_force         — dense appended to sparse, exact CSR product
  * sparse_inverted_index      — same conversion, exact inverted-index scan
  * hamming512                 — 512 Rademacher sign bits, Hamming scan,
                                 overfetch 5000, exact rerank
  * dense_pq_reorder           — PQ over the dense component only, overfetch,
                                 exact rerank
  * sparse_only                — inverted index over the sparse component only,
                                 optional exact rerank
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np
import scipy.sparse as sp

import jax.numpy as jnp

from .pq import adc_lut, adc_scores_ref, pq_encode, train_codebooks

__all__ = [
    "BaselineResult", "dense_brute_force", "sparse_brute_force",
    "sparse_inverted_index", "hamming512", "dense_pq_reorder", "sparse_only",
    "exact_topk", "recall_at_h",
]


@dataclasses.dataclass
class BaselineResult:
    name: str
    ids: np.ndarray
    scores: np.ndarray
    seconds: float


def _topk(scores: np.ndarray, h: int):
    idx = np.argpartition(-scores, min(h, scores.shape[1] - 1), axis=1)[:, :h]
    part = np.take_along_axis(scores, idx, axis=1)
    order = np.argsort(-part, axis=1)
    return np.take_along_axis(idx, order, axis=1), np.take_along_axis(part, order, axis=1)


def exact_topk(q_sparse, q_dense, x_sparse, x_dense, h: int):
    scores = np.asarray((q_sparse @ x_sparse.T).todense(), np.float32)
    scores += np.asarray(q_dense, np.float32) @ np.asarray(x_dense, np.float32).T
    return _topk(scores, h)


def recall_at_h(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    hits = 0
    for f, t in zip(found_ids, true_ids):
        hits += len(set(f.tolist()) & set(t.tolist()))
    return hits / true_ids.size


# ---------------------------------------------------------------------------

def dense_brute_force(q_sparse, q_dense, x_sparse, x_dense, h: int = 20):
    """Pad 0's to the sparse component; everything dense."""
    xd = np.hstack([np.asarray(x_sparse.todense(), np.float32),
                    np.asarray(x_dense, np.float32)])
    qd = np.hstack([np.asarray(q_sparse.todense(), np.float32),
                    np.asarray(q_dense, np.float32)])
    t0 = time.perf_counter()
    scores = qd @ xd.T
    ids, sc = _topk(scores, h)
    return BaselineResult("dense_brute_force", ids, sc, time.perf_counter() - t0)


def _hybrid_as_sparse(x_sparse, x_dense):
    return sp.hstack([x_sparse.tocsr(),
                      sp.csr_matrix(np.asarray(x_dense, np.float32))]).tocsr()


def sparse_brute_force(q_sparse, q_dense, x_sparse, x_dense, h: int = 20):
    """Append dense dims to the sparse representation; exact CSR product."""
    x_all = _hybrid_as_sparse(x_sparse, x_dense)
    q_all = _hybrid_as_sparse(q_sparse, q_dense)
    t0 = time.perf_counter()
    scores = np.asarray((q_all @ x_all.T).todense(), np.float32)
    ids, sc = _topk(scores, h)
    return BaselineResult("sparse_brute_force", ids, sc, time.perf_counter() - t0)


def sparse_inverted_index(q_sparse, q_dense, x_sparse, x_dense, h: int = 20):
    """Exact accumulation over inverted lists (CSC), the paper's exact
    inverted-index baseline (dense dims become full lists — the pathology the
    paper calls out)."""
    x_all = _hybrid_as_sparse(x_sparse, x_dense).tocsc()
    q_all = _hybrid_as_sparse(q_sparse, q_dense).tocsr()
    n = x_all.shape[0]
    t0 = time.perf_counter()
    out_ids = np.zeros((q_all.shape[0], h), np.int64)
    out_sc = np.zeros((q_all.shape[0], h), np.float32)
    for i in range(q_all.shape[0]):
        acc = np.zeros(n, np.float32)
        lo, hi = q_all.indptr[i], q_all.indptr[i + 1]
        for j, qv in zip(q_all.indices[lo:hi], q_all.data[lo:hi]):
            clo, chi = x_all.indptr[j], x_all.indptr[j + 1]
            acc[x_all.indices[clo:chi]] += qv * x_all.data[clo:chi]
        ids, sc = _topk(acc[None], h)
        out_ids[i], out_sc[i] = ids[0], sc[0]
    return BaselineResult("sparse_inverted_index", out_ids, out_sc,
                          time.perf_counter() - t0)


def hamming512(q_sparse, q_dense, x_sparse, x_dense, h: int = 20,
               bits: int = 512, overfetch: int = 5000, seed: int = 0):
    """Paper's hashing baseline: project on `bits` Rademacher vectors, median
    threshold, Hamming scan, exact rerank of `overfetch`."""
    rng = np.random.default_rng(seed)
    d_s = x_sparse.shape[1]
    d_d = x_dense.shape[1]
    r_s = rng.choice([-1.0, 1.0], size=(d_s, bits)).astype(np.float32)
    r_d = rng.choice([-1.0, 1.0], size=(d_d, bits)).astype(np.float32)
    xp = np.asarray(x_sparse @ r_s) + np.asarray(x_dense, np.float32) @ r_d
    med = np.median(xp, axis=0)
    x_bits = np.packbits(xp > med, axis=1)
    qp = np.asarray(q_sparse @ r_s) + np.asarray(q_dense, np.float32) @ r_d

    t0 = time.perf_counter()
    q_bits = np.packbits(qp > med, axis=1)
    # Hamming distance via XOR popcount.
    pop = np.unpackbits(x_bits[None, :, :] ^ q_bits[:, None, :], axis=2).sum(axis=2)
    cand, _ = _topk(-pop.astype(np.float32), min(overfetch, xp.shape[0]))
    ids, sc = _rerank_exact(cand, q_sparse, q_dense, x_sparse, x_dense, h)
    return BaselineResult("hamming512", ids, sc, time.perf_counter() - t0)


def _rerank_exact(cand, q_sparse, q_dense, x_sparse, x_dense, h):
    qn = cand.shape[0]
    out_ids = np.zeros((qn, h), np.int64)
    out_sc = np.zeros((qn, h), np.float32)
    xs = x_sparse.tocsr()
    xd = np.asarray(x_dense, np.float32)
    qs = q_sparse.tocsr()
    qd = np.asarray(q_dense, np.float32)
    for i in range(qn):
        c = cand[i]
        sc = np.asarray((qs[i] @ xs[c].T).todense())[0] + qd[i] @ xd[c].T
        ids, s = _topk(sc[None], h)
        out_ids[i] = c[ids[0]]
        out_sc[i] = s[0]
    return out_ids, out_sc


def dense_pq_reorder(q_sparse, q_dense, x_sparse, x_dense, h: int = 20,
                     overfetch: int = 10000, subspaces: int | None = None,
                     seed: int = 0):
    """Paper baseline 'Dense PQ, Reordering 10k': PQ over the dense component
    only, overfetch, exact hybrid rerank."""
    xd = jnp.asarray(np.asarray(x_dense, np.float32))
    k = subspaces or max(x_dense.shape[1] // 2, 1)
    cb = train_codebooks(xd, k, 16, seed=seed)
    codes = pq_encode(xd, cb)
    t0 = time.perf_counter()
    lut = adc_lut(jnp.asarray(np.asarray(q_dense, np.float32)), cb)
    scores = np.asarray(adc_scores_ref(codes, lut))
    cand, _ = _topk(scores, min(overfetch, scores.shape[1]))
    ids, sc = _rerank_exact(cand, q_sparse, q_dense, x_sparse, x_dense, h)
    return BaselineResult("dense_pq_reorder", ids, sc, time.perf_counter() - t0)


def sparse_only(q_sparse, q_dense, x_sparse, x_dense, h: int = 20,
                overfetch: int | None = None):
    """Paper baselines 'Sparse Inverted Index, No Reordering / Reordering 20k'."""
    x_s = x_sparse.tocsc()
    t0 = time.perf_counter()
    scores = np.asarray((q_sparse @ x_s.T).todense(), np.float32)
    if overfetch is None:
        ids, sc = _topk(scores, h)
        name = "sparse_only_no_reorder"
    else:
        cand, _ = _topk(scores, min(overfetch, scores.shape[1]))
        ids, sc = _rerank_exact(cand, q_sparse, q_dense, x_sparse, x_dense, h)
        name = f"sparse_only_reorder{overfetch}"
    return BaselineResult(name, ids, sc, time.perf_counter() - t0)
