"""Streaming mutable index: delta shard + tombstones + compaction
(DESIGN.md §6; the paper's §7 deployment assumption that the index keeps
serving while the corpus changes).

The batch pipeline (core/hybrid.py) freezes everything at build time:
codebooks, residual quantization grid, compact column space, cache-sort
order.  Mutation therefore splits into two tiers:

* ``DeltaShard`` — a small append-friendly side index holding rows inserted
  since the last build.  Device arrays are sized to an amortized-doubling
  *capacity* (stable shapes => the jit cache grows O(log inserts), the same
  argument as the serving layer's batch buckets); a ``valid_mask`` of
  additive 0/-inf scores tombstones dead slots on device, so they can never
  crowd live rows out of any pass's top-k.  New rows are encoded against the
  FROZEN main-index artifacts: PQ codes via the existing codebooks
  (``core.pq.encode_rows``, packed two-per-byte on append when the main
  index is packed, odd-K phantom nibble included), int8 dense residual via
  the frozen scale/zero grid (``scalar_quantize_rows``), and sparse entries
  as delta posting lists (``sparse_index.DeltaPostings``) over the frozen
  compact column space.  Sparse dims unseen by the main build stay buffered
  in the retained corpus row and only become searchable after compaction.

* ``MutableState`` — the host-side source of truth: the retained corpus
  (initial build rows + appended rows), per-row alive flags, the delta
  shard, and the set of *main tombstones* (external ids deleted or
  superseded while resident in the main generation; the search merge drops
  them host-side).  ``compact()`` folds everything down.  Two policies
  (DESIGN.md §6.2): ``retrain=True`` re-runs the deterministic batch build
  on the surviving rows in corpus order — bit-identical to a scratch build,
  which is what the incremental-vs-rebuild equivalence property pins
  (tests/test_streaming.py) — while the default *merge* path
  (``merge_compact``) keeps every frozen artifact (codebooks, quant grid,
  column space, head-dim set) and only re-derives the row-parallel
  structures, trading a k-means retrain for an O(n) re-encode; its scores
  drift from a scratch build only by the dense encoding error, pinned by
  the relaxed-equivalence property suite.

``HybridIndex.build(..., mutable=True)`` attaches a ``MutableState``;
``HybridIndex.insert/delete/compact`` are thin wrappers over this module,
and ``serve/query_service.py`` serves the delta as one more engine in its
shard fan-out.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from .distributed import ceil16, merge_topk_host
from .engine import IndexArrays, ScoringEngine, tombstone_mask
from .pq import PQCodebooks, ScalarQuant, encode_rows, scalar_quantize_rows
from .sparse_index import (CompactColumns, DeltaPostings,
                           PaddedInvertedIndex, PaddedSparseRows)

__all__ = ["DeltaShard", "DeltaSnapshot", "MutableState", "search_mutable",
           "plan_overfetch", "fanout_search"]


@jax.jit
def _append_batch(codes, resq, rcols, rvals, inv_rows, inv_vals, start,
                  c_rows, r_rows, rc_rows, rv_rows, dims, p_rows, p_vals):
    """ONE fused device dispatch per insert batch (ROADMAP item; DESIGN.md
    §6.1): the appended slots land as a contiguous
    ``lax.dynamic_update_slice`` block into each structural array, and the
    touched dims' posting rectangles as one row scatter — O(rows appended)
    transfer instead of re-uploading the whole shard.  ``dims`` are padded
    to a power-of-two count with repeats of a real dim (duplicate indices
    carry identical rows), so the jit cache grows with
    (batch size, log touched-dims, log capacity), not per insert."""
    dus = jax.lax.dynamic_update_slice
    return (dus(codes, c_rows, (start, 0)), dus(resq, r_rows, (start, 0)),
            dus(rcols, rc_rows, (start, 0)), dus(rvals, rv_rows, (start, 0)),
            inv_rows.at[dims].set(p_rows), inv_vals.at[dims].set(p_vals))


@jax.jit
def _append_batch_rows(codes, resq, rcols, rvals, start,
                       c_rows, r_rows, rc_rows, rv_rows):
    """Row-only variant of ``_append_batch`` for inserts that touched no
    posting list (pure-dense rows, or everything spilled past the cap)."""
    dus = jax.lax.dynamic_update_slice
    return (dus(codes, c_rows, (start, 0)), dus(resq, r_rows, (start, 0)),
            dus(rcols, rc_rows, (start, 0)), dus(rvals, rv_rows, (start, 0)))


@dataclasses.dataclass(frozen=True)
class DeltaSnapshot:
    """One immutable, device-ready view of the delta shard.  Searches hold a
    snapshot for their whole lifetime, so mutations never race a reader —
    the streaming analogue of the service's refcounted generations."""
    arrays: IndexArrays      # capacity-shaped, valid_mask applied
    ids: np.ndarray          # (capacity,) int64 external ids (-1 = empty)
    count: int               # slots ever filled (dead ones included)
    live: int                # slots filled and not tombstoned
    version: int             # mutation counter at snapshot time

    @property
    def capacity(self) -> int:
        """Padded slot count of the device arrays (== arrays.num_points)."""
        return self.arrays.num_points


class DeltaShard:
    """Append-friendly device-resident side index (DESIGN.md §6.1).

    Host mirrors (numpy) are the source of truth; ``snapshot()`` lazily
    materializes an ``IndexArrays`` of the full capacity with a tombstone
    ``valid_mask``.  Slots are append-only — a delete tombstones, an upsert
    tombstones the old slot and appends — and are only reclaimed by
    compaction, which throws the whole shard away.

    Sparse layout: per-dim posting lists capped at ``postings_cap`` entries
    (pass 1), overflow spilled to per-slot residual rows (pass 3).  Both
    serving paths fetch h == capacity from the delta, so every slot is
    pass-3 refined and the split loses nothing; the cap is what keeps the
    pass-1 gather rectangle (d_active, l_max) narrow when a power-law hot
    dim appears in most delta rows.

    Cost model: an INSERT appends incrementally on device — ONE fused
    dispatch writing the appended slots as a contiguous
    ``dynamic_update_slice`` block into every structural array plus a
    scatter of the touched dims' posting rows (O(rows appended) transfer
    instead of re-uploading the whole shard; ``incremental=False`` restores
    the old full re-materialization, kept as the benchmark baseline).
    Capacity / rectangle growth still re-materializes (the shapes
    changed).  A DELETE reuses the structural arrays and swaps only the
    (capacity,) mask leaf.
    """

    def __init__(self, *, codebooks: PQCodebooks, cols: CompactColumns,
                 dense_residual: ScalarQuant, d_dense: int, pack: bool,
                 capacity: int = 64, l_max: int = 4,
                 postings_cap: int | None = 16):
        self.codebooks = codebooks
        self.cols = cols
        self.pack = pack
        self._scale = np.asarray(dense_residual.scale, np.float32)
        self._zero = np.asarray(dense_residual.zero, np.float32)
        self._scale_j = dense_residual.scale      # device copies, shared with
        self._zero_j = dense_residual.zero        # the main generation
        k = codebooks.num_subspaces
        self._kp = (k + 1) // 2 if pack else k
        self.capacity = max(int(capacity), 1)
        self._codes = np.zeros((self.capacity, self._kp), np.uint8)
        self._resq = np.zeros((self.capacity, d_dense), np.int8)
        self._postings = DeltaPostings(cols.num_active, l_max=l_max,
                                       l_cap=postings_cap)
        # per-slot residual rows: postings overflow past l_cap spills here
        # and is scored EXACTLY in pass 3 — both serving paths fetch
        # h == capacity, so every slot is refined and no mass is lost
        self._rmax = 1
        self._row_cols = np.full((self.capacity, self._rmax),
                                 cols.num_active, np.int32)
        self._row_vals = np.zeros((self.capacity, self._rmax), np.float32)
        self._ids = np.full(self.capacity, -1, np.int64)
        self._dead = np.zeros(self.capacity, bool)
        self.count = 0
        self.version = 0
        self.dropped_nnz = 0      # sparse entries outside the compact space
        # incremental device appends (fused dynamic_update_slice batch);
        # False restores full re-materialization per insert (bench baseline)
        self.incremental = True
        # host->device bytes shipped for structural arrays (rebuilds count
        # the whole shard, incremental appends only the new rows) — the
        # transfer-volume claim benchmarks/serve_bench.py records
        self.upload_bytes = 0
        self._snapshot: DeltaSnapshot | None = None
        # structural device arrays (everything but the tombstone mask),
        # invalidated by inserts only: a delete re-uploads just the
        # (capacity,) mask leaf instead of the whole shard
        self._arrays_struct: IndexArrays | None = None

    @property
    def live_count(self) -> int:
        """Rows that are filled and not tombstoned."""
        return self.count - int(self._dead[: self.count].sum())

    def _grow(self, need: int) -> None:
        cap = self.capacity
        while cap < need:
            cap *= 2
        if cap == self.capacity:
            return
        grow = cap - self.capacity
        self._codes = np.pad(self._codes, ((0, grow), (0, 0)))
        self._resq = np.pad(self._resq, ((0, grow), (0, 0)))
        self._row_cols = np.pad(self._row_cols, ((0, grow), (0, 0)),
                                constant_values=self.cols.num_active)
        self._row_vals = np.pad(self._row_vals, ((0, grow), (0, 0)))
        self._ids = np.pad(self._ids, (0, grow), constant_values=-1)
        self._dead = np.pad(self._dead, (0, grow))
        self.capacity = cap

    def _grow_rmax(self, need: int) -> None:
        rmax = self._rmax
        while rmax < need:
            rmax *= 2
        if rmax == self._rmax:
            return
        grow = rmax - self._rmax
        self._row_cols = np.pad(self._row_cols, ((0, 0), (0, grow)),
                                constant_values=self.cols.num_active)
        self._row_vals = np.pad(self._row_vals, ((0, 0), (0, grow)))
        self._rmax = rmax

    def insert_rows(self, x_sparse: sp.spmatrix, x_dense: np.ndarray,
                    ext_ids: np.ndarray) -> np.ndarray:
        """Append rows, encoding against the frozen main-index artifacts.
        Returns the assigned slot numbers."""
        xs = x_sparse.tocsr()
        xd = np.asarray(x_dense, np.float32)
        m = xs.shape[0]
        assert xd.shape[0] == m == len(ext_ids)
        cap0, lmax0, rmax0 = (self.capacity, self._postings.l_max,
                              self._rmax)
        self._grow(self.count + m)
        # dense: PQ codes + residual against frozen codebooks / frozen grid
        codes_u = encode_rows(xd, self.codebooks, pack=False)
        from .pq import pack_codes, pq_decode
        recon = np.asarray(pq_decode(jnp.asarray(codes_u), self.codebooks))
        resq = scalar_quantize_rows(xd - recon, self._scale, self._zero)
        codes_store = pack_codes(codes_u) if self.pack else codes_u
        slots = np.arange(self.count, self.count + m)
        self._codes[slots] = codes_store
        self._resq[slots] = resq
        self._ids[slots] = np.asarray(ext_ids, np.int64)
        # sparse: postings in the frozen compact column space; entries past
        # the per-dim cap spill to the slot's pass-3 residual row
        touched: list[int] = []
        for j, slot in enumerate(slots):
            lo, hi = xs.indptr[j], xs.indptr[j + 1]
            compact = self.cols.to_compact(xs.indices[lo:hi])
            keep = compact < self.cols.num_active
            self.dropped_nnz += int((~keep).sum())
            kept = compact[keep]
            touched.extend(int(d) for d in kept)
            sd, sv = self._postings.append(int(slot), kept,
                                           xs.data[lo:hi][keep])
            if len(sd):
                self._grow_rmax(len(sd))
                self._row_cols[slot, : len(sd)] = sd
                self._row_vals[slot, : len(sd)] = sv
        self.count += m
        self.version += 1
        self._snapshot = None
        if (self.incremental and self._arrays_struct is not None
                and self.capacity == cap0
                and self._postings.l_max == lmax0 and self._rmax == rmax0):
            # device-side append: rows are written in place of the (already
            # correctly sized) structural arrays — O(rows) transfer
            self._incremental_append(slots, np.unique(
                np.asarray(touched, np.int64)))
        else:
            # shape changed (capacity/rectangle growth) or no device copy
            # yet: fall back to full re-materialization at next snapshot()
            self._arrays_struct = None
        return slots

    def _incremental_append(self, slots: np.ndarray,
                            dims: np.ndarray) -> None:
        """Functionally update the structural device arrays with the rows
        just appended — one fused ``_append_batch`` dispatch.  Updates
        build NEW device arrays, so snapshots held by in-flight searches
        keep the leaves they pinned."""
        st = self._arrays_struct
        lo, m = int(slots[0]), len(slots)
        row_args = (jnp.asarray(self._codes[lo:lo + m]),
                    jnp.asarray(self._resq[lo:lo + m]),
                    jnp.asarray(self._row_cols[lo:lo + m]),
                    jnp.asarray(self._row_vals[lo:lo + m]))
        self.upload_bytes += sum(
            a[lo:lo + m].nbytes for a in (self._codes, self._resq,
                                          self._row_cols, self._row_vals))
        inv = st.inv_index
        if dims.size:
            pad = 1 << max(int(np.ceil(np.log2(dims.size))), 0)
            dims_p = np.concatenate(
                [dims, np.full(pad - dims.size, dims[0], dims.dtype)])
            rows_h, vals_h = self._postings.rows_for(dims_p, self.capacity)
            self.upload_bytes += rows_h.nbytes + vals_h.nbytes
            codes, resq, rcols, rvals, irows, ivals = _append_batch(
                st.codes, st.dense_residual.q, st.sparse_residual.cols,
                st.sparse_residual.vals, inv.rows, inv.vals, jnp.int32(lo),
                *row_args, jnp.asarray(dims_p.astype(np.int32)),
                jnp.asarray(rows_h), jnp.asarray(vals_h))
            inv = PaddedInvertedIndex(rows=irows, vals=ivals,
                                      num_points=inv.num_points)
        else:
            codes, resq, rcols, rvals = _append_batch_rows(
                st.codes, st.dense_residual.q, st.sparse_residual.cols,
                st.sparse_residual.vals, jnp.int32(lo), *row_args)
        self._arrays_struct = dataclasses.replace(
            st, codes=codes, inv_index=inv,
            dense_residual=ScalarQuant(q=resq, scale=self._scale_j,
                                       zero=self._zero_j),
            sparse_residual=PaddedSparseRows(cols=rcols, vals=rvals))

    def tombstone(self, slot: int) -> None:
        """Mark one slot dead; its -inf mask row removes it from scoring."""
        if not 0 <= slot < self.count:
            raise IndexError(f"slot {slot} outside filled range "
                             f"[0, {self.count})")
        if not self._dead[slot]:
            self._dead[slot] = True
            self.version += 1
            self._snapshot = None

    def snapshot(self) -> DeltaSnapshot:
        """Materialize (and cache) the device view of the current state.
        Structural arrays are reused across tombstone-only mutations — a
        delete swaps in a fresh (capacity,) mask leaf, nothing else."""
        if self._snapshot is None:
            cap = self.capacity
            if self._arrays_struct is None:
                self.upload_bytes += (
                    self._codes.nbytes + self._resq.nbytes
                    + self._row_cols.nbytes + self._row_vals.nbytes
                    + self._postings._rows.nbytes
                    + self._postings._vals.nbytes)
                self._arrays_struct = IndexArrays.build(
                    codebooks=self.codebooks,
                    codes=jnp.asarray(self._codes),
                    inv_index=self._postings.to_padded(cap),
                    head=None,
                    dense_residual=ScalarQuant(q=jnp.asarray(self._resq),
                                               scale=self._scale_j,
                                               zero=self._zero_j),
                    # capped-postings spill lives here, refined in pass 3
                    sparse_residual=PaddedSparseRows(
                        cols=jnp.asarray(self._row_cols),
                        vals=jnp.asarray(self._row_vals)),
                    num_points=cap, d_active=self.cols.num_active,
                    with_bcsr=False, pre_packed=self.pack)
            arrays = dataclasses.replace(
                self._arrays_struct,
                valid_mask=tombstone_mask(cap, self.count, self._dead))
            self._snapshot = DeltaSnapshot(
                arrays=arrays, ids=self._ids.copy(), count=self.count,
                live=self.live_count, version=self.version)
        return self._snapshot


class MutableState:
    """Host-side mutation bookkeeping attached to a ``HybridIndex`` built
    with ``mutable=True`` (DESIGN.md §6): retained corpus, alive flags,
    delta shard, main tombstones, and the monotone mutation version that
    result caches key on."""

    def __init__(self, index, x_sparse: sp.csr_matrix, x_dense: np.ndarray,
                 ext_ids: np.ndarray | None = None,
                 delta_capacity: int = 64):
        n = x_sparse.shape[0]
        self.params = index.params
        self.x_sparse0 = x_sparse.tocsr()
        self.x_dense0 = np.asarray(x_dense, np.float32)
        self.ids_built = (np.arange(n, dtype=np.int64) if ext_ids is None
                          else np.asarray(ext_ids, np.int64))
        assert len(self.ids_built) == n
        if len(np.unique(self.ids_built)) != n:
            raise ValueError("ext_ids must be unique")
        if n and self.ids_built.min() < 0:
            raise ValueError("external ids must be non-negative (-1 is the "
                             "merge layer's empty-slot sentinel)")
        self.alive0 = np.ones(n, bool)
        # cache-sorted position -> external id, computed ONCE: pi and
        # ids_built are both frozen for this generation, and the search
        # hot path must not re-gather an O(N) map per call
        self.id_map = self.ids_built[index.pi]
        # frozen head-dim set (compact ids, pad -1): merge_compact rebuilds
        # the head block over the SAME dims instead of re-ranking activity,
        # so its tile layout stays comparable across merge generations
        self.head_dims0 = np.asarray(index.head_dim_ids)
        # sparse entries silently outside the frozen column space in the
        # merged MAIN structures (merge_compact carries + grows this);
        # nonzero means only a retrain can make them searchable
        self.main_dropped_nnz = 0
        self.extra_sparse: list[sp.csr_matrix] = []
        self.extra_dense: list[np.ndarray] = []
        self.extra_ids: list[int] = []
        self.extra_alive: list[bool] = []
        self.main_tombstones: set[int] = set()
        self.version = 0
        self.next_id = int(self.ids_built.max(initial=-1)) + 1
        self._loc = {int(e): ("init", i)
                     for i, e in enumerate(self.ids_built)}
        self.delta = DeltaShard(
            codebooks=index.codebooks, cols=index.cols,
            dense_residual=index.dense_residual, d_dense=index.d_dense,
            pack=index.params.resolve_pack(), capacity=delta_capacity)

    # -- mutation ---------------------------------------------------------

    def insert(self, x_sparse, x_dense, ids=None) -> np.ndarray:
        """Insert (or upsert) rows; returns the external ids assigned."""
        xs = sp.csr_matrix(x_sparse)
        if xs.shape[1] != self.x_sparse0.shape[1]:
            raise ValueError(
                f"sparse width {xs.shape[1]} != corpus width "
                f"{self.x_sparse0.shape[1]}")
        xd = np.atleast_2d(np.asarray(x_dense, np.float32))
        if xd.shape[1] != self.x_dense0.shape[1]:
            raise ValueError(
                f"dense width {xd.shape[1]} != corpus width "
                f"{self.x_dense0.shape[1]}")
        m = xs.shape[0]
        if m == 0:
            return np.empty(0, np.int64)
        if ids is None:
            ids = np.arange(self.next_id, self.next_id + m, dtype=np.int64)
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if not (len(ids) == m == xd.shape[0]):
            raise ValueError(
                f"row-count mismatch: {m} sparse, {xd.shape[0]} dense, "
                f"{len(ids)} ids")
        if len(np.unique(ids)) != m:
            raise ValueError("duplicate external ids within one insert batch")
        if ids.min() < 0:
            raise ValueError("external ids must be non-negative (-1 is the "
                             "merge layer's empty-slot sentinel)")
        # encode FIRST, retire old copies after: if validation or encoding
        # raises, the upserted ids' existing rows must survive untouched
        slots = self.delta.insert_rows(xs, xd, ids)
        for e in ids:
            self._kill(int(e))            # upsert: retire any existing row
        for j, (e, _slot) in enumerate(zip(ids, slots)):
            self.extra_sparse.append(xs[j])
            self.extra_dense.append(xd[j])
            self.extra_ids.append(int(e))
            self.extra_alive.append(True)
            self._loc[int(e)] = ("extra", len(self.extra_ids) - 1)
        self.next_id = max(self.next_id, int(ids.max()) + 1)
        self.version += 1
        return ids

    def _kill(self, ext_id: int) -> bool:
        loc = self._loc.get(ext_id)
        if loc is None:
            return False
        kind, i = loc
        if kind == "init":
            if not self.alive0[i]:
                return False
            self.alive0[i] = False
            self.main_tombstones.add(ext_id)
        else:
            if not self.extra_alive[i]:
                return False
            self.extra_alive[i] = False
            self.delta.tombstone(i)       # slot j == extra index j
        del self._loc[ext_id]
        return True

    def delete(self, ids) -> int:
        """Tombstone rows by external id; returns how many were live."""
        killed = 0
        for e in np.atleast_1d(np.asarray(ids, np.int64)):
            killed += self._kill(int(e))
        if killed:
            self.version += 1
        return killed

    # -- compaction -------------------------------------------------------

    @property
    def live_rows(self) -> int:
        """Logical corpus size: surviving initial rows + live inserts."""
        return int(self.alive0.sum()) + sum(self.extra_alive)

    def survivors(self) -> tuple[sp.csr_matrix, np.ndarray, np.ndarray]:
        """Surviving corpus rows in canonical order (initial order, then
        insertion order) — the exact input a from-scratch batch build on the
        current logical contents would receive, which is what the
        equivalence property test relies on."""
        keep0 = np.flatnonzero(self.alive0)
        xs_parts = [self.x_sparse0[keep0]]
        xd_parts = [self.x_dense0[keep0]]
        ids = [self.ids_built[keep0]]
        live = [j for j, a in enumerate(self.extra_alive) if a]
        if live:
            xs_parts += [self.extra_sparse[j] for j in live]
            xd_parts.append(np.stack([self.extra_dense[j] for j in live]))
            ids.append(np.asarray([self.extra_ids[j] for j in live],
                                  np.int64))
        xs = sp.vstack(xs_parts, format="csr") if len(xs_parts) > 1 \
            else xs_parts[0]
        return xs, np.concatenate(xd_parts, axis=0), np.concatenate(ids)

    _EMPTY_COMPACT_MSG = (
        "cannot compact an empty corpus: the batch build (k-means, "
        "column space) needs at least one surviving row; keep the "
        "delta serving or insert before compacting")

    def merge_compact(self):
        """Fold delta + tombstones into the FROZEN build artifacts
        (DESIGN.md §6.2): keep the codebooks, residual-quant grid, compact
        column space and head-dim set, and re-derive only the row-parallel
        structures over the surviving rows — new cache-sort, re-pruned
        posting lists, PQ codes via ``encode_rows`` against the existing
        codebooks, int8 residuals on the existing grid.  O(n) encode
        instead of a k-means retrain; rows already resident in the main
        generation re-encode to IDENTICAL codes (deterministic argmin over
        unchanged codebooks), so merged scores drift from a scratch rebuild
        only by the delta rows' frozen-vs-retrained dense encoding error —
        the tolerance the relaxed-equivalence suite (tests/test_streaming.py)
        pins.  Sparse entries outside the frozen column space stay buffered
        in the retained corpus (counted in ``main_dropped_nnz``) until a
        ``compact(retrain=True)``.  Returns a NEW mutable ``HybridIndex``;
        the caller swaps it in."""
        from .cache_sort import cache_sort
        from .engine import Backend
        from .hybrid import HybridIndex, _remap
        from .pq import pq_decode
        from .pruning import prune_split
        from .sparse_index import (build_padded_inverted_index,
                                   build_padded_rows, build_tile_sparse_head)
        if self.live_rows == 0:
            raise ValueError(self._EMPTY_COMPACT_MSG)
        params, delta = self.params, self.delta
        cols, codebooks = delta.cols, delta.codebooks
        xs, xd, ids = self.survivors()
        n = xs.shape[0]
        pi = cache_sort(xs)
        xs_s, xd_s = xs[pi], np.asarray(xd, np.float32)[pi]
        split = prune_split(xs_s, keep_top=params.keep_top)
        idx_compact = _remap(split.index, cols)     # frozen column space
        res_compact = _remap(split.residual, cols)
        dropped = int(xs_s.nnz) - int(idx_compact.nnz) - int(res_compact.nnz)
        head = None
        head_dim_ids = np.empty(0, np.int32)
        tail_index = idx_compact
        hd = self.head_dims0[self.head_dims0 >= 0].astype(np.int32)
        if hd.size and cols.num_active > 0:
            # same FROZEN head dims, not a re-ranked activity top-n: the
            # query-side head/tail split must match the index layout
            head = build_tile_sparse_head(idx_compact, hd,
                                          block_rows=params.block_rows,
                                          block_cols=params.block_cols)
            head_dim_ids = np.asarray(head.head_dims)
            tail_index = idx_compact.tolil()
            tail_index[:, hd] = 0
            tail_index = tail_index.tocsr()
            tail_index.eliminate_zeros()
        inv_index = build_padded_inverted_index(tail_index)
        sparse_residual = build_padded_rows(res_compact)
        codes_u = encode_rows(xd_s, codebooks, pack=False)
        recon = np.asarray(pq_decode(jnp.asarray(codes_u), codebooks))
        resq = scalar_quantize_rows(xd_s - recon, delta._scale, delta._zero)
        dres = ScalarQuant(q=jnp.asarray(resq), scale=delta._scale_j,
                           zero=delta._zero_j)
        backend = params.resolve_backend()
        arrays = IndexArrays.build(
            codebooks=codebooks, codes=jnp.asarray(codes_u),
            inv_index=inv_index, head=head, dense_residual=dres,
            sparse_residual=sparse_residual, num_points=n,
            d_active=cols.num_active,
            with_bcsr=backend in (Backend.PALLAS, Backend.PALLAS_PACKED),
            pack=params.resolve_pack())
        engine = ScoringEngine(arrays=arrays, backend=backend)
        new = HybridIndex(params=params, num_points=n, pi=pi, cols=cols,
                          inv_index=inv_index, head=head,
                          head_dim_ids=head_dim_ids,
                          sparse_residual=sparse_residual,
                          codebooks=codebooks, codes=arrays.codes,
                          dense_residual=dres, d_dense=xd.shape[1],
                          engine=engine)
        new.mutable_state = MutableState(new, xs, xd, ext_ids=ids,
                                         delta_capacity=delta.capacity)
        new.mutable_state.next_id = max(new.mutable_state.next_id,
                                        self.next_id)
        new.mutable_state.main_dropped_nnz = self.main_dropped_nnz + dropped
        return new

    def compact(self, retrain: bool | None = None):
        """Fold delta + tombstones down; returns a NEW mutable
        ``HybridIndex`` (this state is untouched; the caller swaps, e.g.
        through QueryService's double-buffered ``refresh()``).

        ``retrain=True`` re-runs the full batch build on the surviving rows
        (new codebooks, new compact column space, new cache-sort) —
        bit-identical to building from scratch.  ``retrain=False`` merges
        into the frozen artifacts (``merge_compact``).  The default
        ``None`` auto-routes: merge, unless sparse entries have been
        dropped outside the frozen column space (delta buffering or a
        previous merge) — those only become searchable under a retrain."""
        from .hybrid import HybridIndex
        if self.live_rows == 0:
            raise ValueError(self._EMPTY_COMPACT_MSG)
        if retrain is None:
            retrain = (self.delta.dropped_nnz + self.main_dropped_nnz) > 0
        if not retrain:
            return self.merge_compact()
        xs, xd, ids = self.survivors()
        new = HybridIndex.build(xs, xd, self.params, mutable=True,
                                ext_ids=ids)
        # carry the id counter: the fresh state only sees surviving ids, so
        # recomputing max+1 could re-mint a previously deleted id and
        # resurrect it under new content
        new.mutable_state.next_id = max(new.mutable_state.next_id,
                                        self.next_id)
        return new


def plan_overfetch(engines, h: int, deleted) -> list[int]:
    """Per-main-engine fetch depths under pending tombstones (DESIGN.md
    §6.2): every main engine overfetches by the 16-bucketed tombstone count
    (the bucket keeps the jit-static fetch sizes bounded) so dropping
    tombstoned ids at the merge can never leave fewer than h live results;
    overfetch-then-truncate of a deterministic top-k is exact, so the
    mutation-free path stays bit-identical to the plain one."""
    slack = ceil16(len(deleted)) if deleted else 0
    return [min(h + slack, e.num_points) for e in engines]


def fanout_search(engines, h_fetch, offsets, id_map, delta_engine,
                  delta_ids, deleted, qd, qv, qe, *, h: int, alpha: int,
                  beta: int, qn: int | None = None, executor=None,
                  dedup_upserts: bool = False, timing: dict | None = None):
    """THE fan-out merge (DESIGN.md §6.2): dispatch every main engine plus
    the delta engine back-to-back (JAX async dispatch overlaps them — the
    in-process form of the paper's §7.2 RPC fan-out), assemble the per-
    engine candidates in the common EXTERNAL id space, and merge top-h on
    the host with main-generation tombstones dropped.

    Shared by ``search_mutable`` (one engine, one offset) and
    ``QueryService._run_batch`` (per-shard engines + bucket padding) — one
    implementation instead of the two copies a parity test used to pin.

    engines/h_fetch/offsets: the main engines, their fetch depths
    (``plan_overfetch``), and each engine's global row offset; ``id_map``
    maps global row positions to external ids (None = identity);
    ``delta_engine`` fetches its whole capacity so tombstone-masked slots
    can never crowd out live ones, with ``delta_ids`` mapping slots to
    external ids (``delta_ids=None`` when the delta engine already returns
    EXTERNAL ids — the RPC delta part of the cluster tier); ``qn`` trims
    bucket padding before the merge.  Engines are any ``ShardSearcher``
    duck-type — ``.search(qd, qv, qe, h=, alpha=, beta=) -> (scores, ids)``
    plus ``.num_points`` — so in-process ``ScoringEngine`` and the cluster
    tier's RPC shard handles dispatch through the same code (DESIGN.md
    §8.2).  ``executor`` (a ``concurrent.futures`` executor) runs the
    dispatches concurrently — required for BLOCKING remote engines, where
    back-to-back calls would serialize the network round-trips; the
    in-process path leaves it None because JAX async dispatch already
    overlaps device work.  ``dedup_upserts`` forwards to
    ``merge_topk_host`` (see its docstring for the cross-transport upsert
    race it closes).  ``timing``, when a dict, receives ``dispatch_s``
    (dispatch + collect of every engine) and ``merge_s`` (host assembly +
    top-h merge) wall seconds — the span tags ``QueryService`` feeds its
    ``serve.batch`` children (DESIGN.md §9.2; note JAX async dispatch can
    defer device sync into the assembly step, so on the in-process path
    ``merge_s`` includes the device wait).  Returns ``(scores, ids)
    (qn, h)`` numpy arrays.
    """
    t0 = time.perf_counter()
    if executor is not None:
        futs = [executor.submit(e.search, qd, qv, qe, h=hf,
                                alpha=alpha, beta=beta)
                for e, hf in zip(engines, h_fetch)]
        dfut = None
        if delta_engine is not None:
            dfut = executor.submit(delta_engine.search, qd, qv, qe,
                                   h=delta_engine.num_points,
                                   alpha=alpha, beta=beta)
        outs = [f.result() for f in futs]
        delta_out = dfut.result() if dfut is not None else None
    else:
        outs = [e.search(qd, qv, qe, h=hf, alpha=alpha, beta=beta)
                for e, hf in zip(engines, h_fetch)]
        delta_out = None
        if delta_engine is not None:
            delta_out = delta_engine.search(qd, qv, qe,
                                            h=delta_engine.num_points,
                                            alpha=alpha, beta=beta)
    t1 = time.perf_counter()
    # assemble per-engine candidate parts in a COMMON id space.  Shards
    # stay in row order so stable-sort tie-breaking matches lax.top_k on
    # the unsharded array.
    parts = []
    for out, off in zip(outs, offsets):
        s = np.asarray(out[0])
        ids = np.asarray(out[1]).astype(np.int64)
        if qn is not None:
            s, ids = s[:qn], ids[:qn]
        ids = ids + int(off)
        if id_map is not None:
            ids = np.asarray(id_map)[ids]
        parts.append((s, ids, True))
    if delta_out is not None:
        s = np.asarray(delta_out[0])
        pos = np.asarray(delta_out[1]).astype(np.int64)
        if qn is not None:
            s, pos = s[:qn], pos[:qn]
        parts.append((s, pos if delta_ids is None else delta_ids[pos],
                      False))
    out = merge_topk_host(parts, h, drop_ids=deleted,
                          dedup_upserts=dedup_upserts)
    if timing is not None:
        timing["dispatch_s"] = t1 - t0
        timing["merge_s"] = time.perf_counter() - t1
    return out


def search_mutable(index, q_sparse, q_dense, h: int = 20,
                   alpha: int | None = None, beta: int | None = None):
    """Three-pass search over main generation + delta shard with host merge
    (DESIGN.md §6.2) — the single-process form of what QueryService does in
    its fan-out (literally the same ``fanout_search`` helper).  Returns a
    SearchResult whose ids are EXTERNAL ids."""
    from .hybrid import SearchResult
    from .sparse_index import sparse_queries_to_padded

    st = index.mutable_state
    p = index.params
    alpha = p.alpha if alpha is None else alpha
    beta = p.beta if beta is None else beta
    q_dims, q_vals = sparse_queries_to_padded(q_sparse, index.cols,
                                              nq_max=p.nq_max)
    qd, qv = jnp.asarray(q_dims), jnp.asarray(q_vals)
    qe = jnp.asarray(np.asarray(q_dense, np.float32))

    h_fetch = plan_overfetch([index.engine], h, st.main_tombstones)
    snap = st.delta.snapshot() if st.delta.live_count else None
    delta_engine = None
    if snap is not None:
        delta_engine = ScoringEngine(arrays=snap.arrays,
                                     backend=index.engine.backend)
    s, ids = fanout_search(
        [index.engine], h_fetch, np.zeros(1, np.int64),
        st.id_map, delta_engine,
        snap.ids if snap is not None else None, st.main_tombstones,
        qd, qv, qe, h=h, alpha=alpha, beta=beta)
    return SearchResult(ids=ids, scores=s)
