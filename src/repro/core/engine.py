"""Device-resident three-pass scoring engine (the paper's full search loop).

One layer owns the paper's scorer instead of three call-site copies
(HybridIndex.search, distributed._pass1_local, serve/hybrid_head):

* ``IndexArrays`` — a pytree-registered dataclass holding every
  device-resident index structure: PQ codes + LUT-ready codebooks, the padded
  inverted index, the tile head both as a dense block (ref path) and in BCSR
  form (Pallas path), the int8 dense residual and the padded sparse residual.
  Being a pytree it moves through ``jax.jit`` / ``shard_map`` / donation as a
  single argument.

* ``ScoringEngine`` — runs the ENTIRE three-pass search (pass 1 approximate
  sparse+dense scores → pass 2 dense residual → pass 3 sparse residual, with
  ``lax.top_k`` between passes) as ONE jitted function: no host transfer or
  dispatch between passes.

* ``Backend`` — pluggable scoring backend (DESIGN.md §3):
    ref           pure-jnp gather ADC + dense head matmul (bit-tight oracle)
    onehot-mxu    MXU one-hot contraction ADC (kernels/ops.lut16_adc_onehot)
    pallas        LUT16 + block-sparse Pallas kernels (kernels/ops)
    pallas-packed LUT16 over packed 4-bit codes, two per byte (§6.1.1's
                  storage): the pass-1 HBM code stream — the bound on
                  single-query throughput (§4.1.2) — halves.  IndexArrays
                  built with ``pack=True`` stores ONLY the packed form;
                  ref/onehot backends unpack in-jit (bit-for-bit vs unpacked).

Call sites: core/hybrid.py (build/permute wrapper), core/distributed.py
(shard_map over pass-1 and the full three-pass refinement), and
serve/hybrid_head.py (ADC + residual reorder over an LM head).
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import residual as res
from .pq import PQCodebooks, ScalarQuant, adc_lut, adc_scores_ref
from .sparse_index import (PaddedInvertedIndex, PaddedSparseRows,
                           TileSparseHead, score_head_ref, score_inverted)

__all__ = [
    "Backend", "IndexArrays", "ScoringEngine", "adc_scores",
    "scatter_queries_compact", "scatter_head_queries", "pass1_bias",
    "pass1_scores", "three_pass_search", "query_fingerprint",
    "release_index_arrays", "tombstone_mask",
]


class Backend(enum.Enum):
    """Which implementation scores pass 1 (dense ADC + head block)."""
    REF = "ref"
    ONEHOT = "onehot-mxu"
    PALLAS = "pallas"
    PALLAS_PACKED = "pallas-packed"

    @classmethod
    def from_name(cls, name: "Backend | str | None") -> "Backend":
        if name is None:
            return cls.REF
        if isinstance(name, Backend):
            return name
        aliases = {"ref": cls.REF, "gather": cls.REF,
                   "onehot": cls.ONEHOT, "onehot-mxu": cls.ONEHOT,
                   "pallas": cls.PALLAS, "lut16": cls.PALLAS,
                   "pallas-packed": cls.PALLAS_PACKED,
                   "packed": cls.PALLAS_PACKED,
                   "lut16-packed": cls.PALLAS_PACKED}
        try:
            return aliases[name]
        except KeyError:
            raise ValueError(
                f"unknown backend {name!r}; expected one of {sorted(aliases)}"
            ) from None


def adc_scores(codes: jax.Array, lut: jax.Array,
               backend: Backend = Backend.REF, *,
               packed: bool | None = None) -> jax.Array:
    """Dense ADC scan codes × (Q, K, l) LUT -> (Q, N), by backend.

    packed: codes hold two 4-bit subspace codes per byte, (N, ceil(K/2)) from
    kernels pack_codes.  None => packed iff backend is PALLAS_PACKED.  The
    Pallas backends unpack in VMEM (half the HBM stream); ref/onehot unpack
    in-jit first and then score exactly like the unpacked path — bit-for-bit,
    so packed storage stays comparable against the oracle."""
    if packed is None:
        packed = backend is Backend.PALLAS_PACKED
    if backend in (Backend.PALLAS, Backend.PALLAS_PACKED):
        from repro.kernels.ops import lut16_adc
        return lut16_adc(codes, lut, packed=packed)
    if packed:
        from repro.kernels.ops import unpack_codes
        codes = unpack_codes(codes, lut.shape[-2])
    if backend is Backend.ONEHOT:
        from repro.kernels.ops import lut16_adc_onehot
        return lut16_adc_onehot(codes, lut)
    return adc_scores_ref(codes, lut)


# ---------------------------------------------------------------------------
# IndexArrays — everything search needs, resident on device
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IndexArrays:
    codebooks: PQCodebooks             # LUT-ready PQ codebooks (K, l, p)
    codes: jax.Array                   # (N, K) uint8 PQ codes, or
                                       # (N, ceil(K/2)) when codes_packed
    inv_index: PaddedInvertedIndex     # tail dims of the pruned data index
    head: TileSparseHead | None        # head dims (None => no head block)
    head_pos: jax.Array                # (d_active+1,) compact dim -> head slot
    head_tiles: jax.Array              # BCSR tiles (T, Br, Bc) of the head
    head_ptr: jax.Array                # (N_pad/Br + 1,) int32
    head_col: jax.Array                # (T,) int32
    dense_residual: ScalarQuant        # int8 residual of the dense component
    sparse_residual: PaddedSparseRows  # eps-pruned sparse residual rows
    num_points: int = dataclasses.field(metadata=dict(static=True))
    d_active: int = dataclasses.field(metadata=dict(static=True))
    head_max_steps: int = dataclasses.field(metadata=dict(static=True))
    codes_packed: bool = dataclasses.field(
        default=False, metadata=dict(static=True))
    # (N,) float32 additive row mask: 0 for live rows, -inf for tombstoned or
    # not-yet-filled slots (DESIGN.md §6).  None (the default, and the only
    # value the batch build produces) means every row is live.  The mask is a
    # pytree leaf, so a delta shard can retire rows without reshaping — the
    # jit cache only grows when the capacity doubles.
    valid_mask: jax.Array | None = None

    @classmethod
    def build(cls, *, codebooks: PQCodebooks, codes: jax.Array,
              inv_index: PaddedInvertedIndex, head: TileSparseHead | None,
              dense_residual: ScalarQuant, sparse_residual: PaddedSparseRows,
              num_points: int, d_active: int,
              with_bcsr: bool = True, pack: bool = False,
              pre_packed: bool = False,
              valid_mask: jax.Array | None = None) -> "IndexArrays":
        """Host-side assembly: derives the head query scatter table and the
        BCSR form once, so search never leaves the device.

        with_bcsr=False skips the BCSR conversion (build time + HBM) for
        engines that never take the Pallas head path; _head_scores falls back
        to the dense matmul when the tiles are absent.

        pack=True stores the dense PQ codes packed two-per-byte (paper
        §6.1.1) — the ONLY resident copy, halving the code HBM footprint and
        the pass-1 scan stream.  Requires l <= 16 codewords (4 bits); the
        PALLAS_PACKED kernel additionally needs l == 16 — ScoringEngine
        enforces that pairing at construction.  Odd K gets a zero phantom
        nibble that every scoring path masks out.

        pre_packed=True declares that ``codes`` are ALREADY two-per-byte
        (e.g. a delta shard that packs row by row on append, DESIGN.md §6) —
        the packed flag is set without re-packing.  valid_mask forwards the
        (N,) live/tombstone mask; the batch build leaves it None."""
        pos = np.full(d_active + 1, 0, np.int32)
        tiles = jnp.zeros((1, 1, 1), jnp.float32)
        ptr = jnp.zeros((2,), jnp.int32)
        col = jnp.zeros((1,), jnp.int32)
        max_steps = 0
        if head is not None:
            d_head_pad = head.block.shape[1]
            pos = np.full(d_active + 1, d_head_pad, np.int32)
            hd = np.asarray(head.head_dims)
            valid = np.flatnonzero(hd >= 0)
            pos[hd[valid]] = valid.astype(np.int32)
            if with_bcsr:
                from repro.kernels.ops import bcsr_from_head
                tiles, ptr, col, max_steps = bcsr_from_head(head)
        if pack and pre_packed:
            raise ValueError("pass pack=True (pack now) or pre_packed=True "
                             "(already packed), not both")
        if pack or pre_packed:
            if codebooks.num_codes > 16:
                raise ValueError(
                    "packed codes need l <= 16 codewords (4 bits), got "
                    f"l={codebooks.num_codes}")
        if pack:
            from repro.kernels.ops import pack_codes
            codes = jnp.asarray(pack_codes(np.asarray(codes)))
        return cls(codebooks=codebooks, codes=codes, inv_index=inv_index,
                   head=head, head_pos=jnp.asarray(pos), head_tiles=tiles,
                   head_ptr=ptr, head_col=col, dense_residual=dense_residual,
                   sparse_residual=sparse_residual, num_points=num_points,
                   d_active=d_active, head_max_steps=max_steps,
                   codes_packed=pack or pre_packed, valid_mask=valid_mask)


# ---------------------------------------------------------------------------
# Jittable building blocks
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(2,))
def scatter_queries_compact(q_dims: jax.Array, q_vals: jax.Array,
                            d_active: int) -> jax.Array:
    """(Q, nq) padded sparse queries -> (Q, d_active + 1) dense w/ pad slot."""
    qn = q_dims.shape[0]
    out = jnp.zeros((qn, d_active + 1), jnp.float32)
    qidx = jnp.arange(qn)[:, None]
    out = out.at[jnp.broadcast_to(qidx, q_dims.shape), q_dims].add(
        q_vals, mode="drop")
    return out.at[:, d_active].set(0.0)


def scatter_head_queries(q_dims: jax.Array, q_vals: jax.Array,
                         head_pos: jax.Array, d_head_pad: int) -> jax.Array:
    """Scatter padded sparse queries into the dense head subspace on device.

    head_pos maps compact dim ids (plus the pad sentinel d_active) to head
    slots; non-head dims map to the trailing pad slot, sliced off."""
    qn = q_dims.shape[0]
    pos = jnp.take(head_pos, q_dims, axis=0, mode="clip")       # (Q, nq)
    out = jnp.zeros((qn, d_head_pad + 1), jnp.float32)
    qidx = jnp.arange(qn)[:, None]
    out = out.at[jnp.broadcast_to(qidx, pos.shape), pos].add(
        q_vals, mode="drop")
    return out[:, :d_head_pad]


def _head_scores(arrays: IndexArrays, q_head: jax.Array,
                 backend: Backend) -> jax.Array:
    # head_max_steps == 0 marks arrays built without BCSR (with_bcsr=False);
    # fall back to the dense matmul, which is always correct
    if (backend in (Backend.PALLAS, Backend.PALLAS_PACKED)
            and arrays.head_max_steps > 0):
        from repro.kernels.ops import block_sparse_matmul_bcsr
        return block_sparse_matmul_bcsr(
            q_head, arrays.head_tiles, arrays.head_ptr, arrays.head_col,
            max_steps=arrays.head_max_steps)
    return score_head_ref(arrays.head, q_head)


def pass1_bias(arrays: IndexArrays, q_dims: jax.Array, q_vals: jax.Array,
               backend: Backend = Backend.REF) -> jax.Array:
    """The sparse half of pass 1: inverted-index tail + head block.  (Q, N).

    This is the per-(query, row) additive bias the fused scan-and-select
    kernel (DESIGN.md §2.5) folds into its select step — the dense ADC term
    and the tombstone mask are NOT included here."""
    sparse = score_inverted(arrays.inv_index, q_dims, q_vals)
    if arrays.head is not None:
        q_head = scatter_head_queries(q_dims, q_vals, arrays.head_pos,
                                      arrays.head.block.shape[1])
        head_s = _head_scores(arrays, q_head, backend)
        sparse = sparse + head_s[:, : arrays.num_points]
    return sparse


def pass1_scores(arrays: IndexArrays, q_dims: jax.Array, q_vals: jax.Array,
                 lut: jax.Array, backend: Backend = Backend.REF) -> jax.Array:
    """Pass-1 approximate hybrid scores over the full (local) shard:
    inverted-index sparse + head-block sparse + LUT ADC dense.  (Q, N).

    When the arrays carry a ``valid_mask`` (delta shard, DESIGN.md §6) it is
    added here, so tombstoned and empty slots score -inf and can never crowd
    live rows out of ANY pass's top-k — the later passes only add finite
    residual terms to -inf.  (The mask is folded into the sparse bias BEFORE
    the dense term; adding 0.0 is exact and -inf absorbs, so the result is
    bit-identical to masking last — and matches the fused kernel's
    bias-at-select ordering.)"""
    bias = pass1_bias(arrays, q_dims, q_vals, backend)
    if arrays.valid_mask is not None:
        bias = bias + arrays.valid_mask[None, :]
    dense = adc_scores(arrays.codes, lut, backend, packed=arrays.codes_packed)
    return bias + dense


def tombstone_mask(capacity: int, count: int,
                   dead: np.ndarray | None = None) -> jax.Array:
    """(capacity,) additive row mask for a delta shard: 0 for live slots,
    -inf for tombstoned slots and slots at/after ``count`` (never filled).
    ``dead``: optional (capacity,) bool of tombstoned slots."""
    live = np.arange(capacity) < count
    if dead is not None:
        live &= ~np.asarray(dead, bool)
    return jnp.asarray(np.where(live, 0.0, -np.inf).astype(np.float32))


def _use_fused_pass1(arrays: IndexArrays, backend: Backend, fused: bool,
                     k: int) -> bool:
    """Static routing decision for the fused scan-and-select pass 1.

    Only the Pallas backends have the fused kernel; k must fit the VMEM
    candidate buffer (MAX_FUSED_CANDIDATES) or the op would fall back to
    materialize-then-topk anyway — routing through pass1_scores keeps the
    jaxpr honest about what actually runs."""
    from repro.kernels.ops import MAX_FUSED_CANDIDATES
    return (fused and backend in (Backend.PALLAS, Backend.PALLAS_PACKED)
            and k <= MAX_FUSED_CANDIDATES)


def _fused_pass1_topk(arrays: IndexArrays, q_dims: jax.Array,
                      q_vals: jax.Array, lut: jax.Array, k: int,
                      backend: Backend):
    """Pass-1 top-k via the fused scan-and-select kernel (DESIGN.md §2.5):
    the (Q, N) dense score matrix is never written to HBM — the sparse bias
    is folded in at the kernel's select step, bit-identical to
    pass1_scores + top_k."""
    from repro.kernels.ops import lut16_adc_topk
    bias = pass1_bias(arrays, q_dims, q_vals, backend)
    return lut16_adc_topk(arrays.codes, lut, k, bias=bias,
                          row_mask=arrays.valid_mask,
                          packed=arrays.codes_packed)


@partial(jax.jit, static_argnames=("h", "c1", "c2", "backend", "fused"))
def three_pass_search(arrays: IndexArrays, q_dims: jax.Array,
                      q_vals: jax.Array, q_dense: jax.Array, *, h: int,
                      c1: int, c2: int, backend: Backend = Backend.REF,
                      fused: bool = True):
    """The paper's full search as ONE jitted function — no host sync between
    passes.  Returns (scores (Q, h), ids (Q, h), pass1 ids (Q, c1)); ids are
    positions in cache-sorted row order (callers map through pi).

    ``fused`` (default on) routes pass 1 through the fused scan-and-select
    kernel on the Pallas backends whenever c1 fits the candidate buffer —
    same (scores, ids) bit-for-bit, minus the (Q, N) HBM round-trip."""
    lut = adc_lut(q_dense, arrays.codebooks)

    # pass 1: approximate scores on the full shard, overfetch c1
    if _use_fused_pass1(arrays, backend, fused, c1):
        s1, ids1 = _fused_pass1_topk(arrays, q_dims, q_vals, lut, c1, backend)
    else:
        approx = pass1_scores(arrays, q_dims, q_vals, lut, backend)
        s1, ids1 = res.topk_candidates(approx, c1)

    # pass 2: + dense residual, keep c2
    extra_d = res.dense_residual_scores(arrays.dense_residual, ids1, q_dense)
    s2, ids2 = res.reorder_pass(s1, ids1, extra_d, c2)

    # pass 3: + sparse residual, return h
    q_cols = scatter_queries_compact(q_dims, q_vals, arrays.d_active)
    extra_s = res.sparse_residual_scores(arrays.sparse_residual, ids2, q_cols)
    s3, ids3 = res.reorder_pass(s2, ids2, extra_s, h)
    return s3, ids3, ids1


# ---------------------------------------------------------------------------
# ScoringEngine — thin stateful façade over the jitted search
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScoringEngine:
    """Owns the device-resident index + backend choice.

    ``search`` resolves the per-pass candidate counts (static ints, so each
    (h, alpha, beta) pair compiles once) and dispatches the single-jit
    three-pass search.  ``fused`` (default on) lets the Pallas backends take
    the fused scan-and-select pass 1 (DESIGN.md §2.5); turn it off to force
    materialize-then-topk (the A/B baseline benchmarks use)."""
    arrays: IndexArrays
    backend: Backend = Backend.REF
    fused: bool = True

    def __post_init__(self):
        # fail at construction, not at the first search deep inside the
        # kernel wrapper: the packed Pallas kernel's LUT last dim is 16.
        if (self.backend is Backend.PALLAS_PACKED and self.arrays.codes_packed
                and self.arrays.codebooks.num_codes != 16):
            raise ValueError(
                "Backend.PALLAS_PACKED requires l == 16 codewords, got "
                f"l={self.arrays.codebooks.num_codes}; scan packed codes "
                "with smaller codebooks via the ref/onehot-mxu backends")

    @property
    def num_points(self) -> int:
        return self.arrays.num_points

    def candidate_counts(self, h: int, alpha: int, beta: int) -> tuple[int, int]:
        c1 = min(max(alpha * h, h), self.num_points)
        c2 = min(max(beta * h, h), c1)
        return c1, c2

    def search(self, q_dims: jax.Array, q_vals: jax.Array,
               q_dense: jax.Array, *, h: int, alpha: int, beta: int):
        """Three-pass device search.  Returns (scores, ids, pass1_ids) in
        cache-sorted row positions."""
        c1, c2 = self.candidate_counts(h, alpha, beta)
        return three_pass_search(self.arrays, q_dims, q_vals, q_dense,
                                 h=h, c1=c1, c2=c2, backend=self.backend,
                                 fused=self.fused)

    def pass1_topk(self, q_dims: jax.Array, q_vals: jax.Array,
                   lut: jax.Array, k: int):
        """Pass-1-only local top-k (the distributed fan-out building block)."""
        if _use_fused_pass1(self.arrays, self.backend, self.fused, k):
            return _fused_pass1_topk(self.arrays, q_dims, q_vals, lut, k,
                                     self.backend)
        scores = pass1_scores(self.arrays, q_dims, q_vals, lut, self.backend)
        return res.topk_candidates(scores, k)


# ---------------------------------------------------------------------------
# Serving hooks (DESIGN.md §5): result-cache fingerprints and the donation
# hook for double-buffered IndexArrays swaps
# ---------------------------------------------------------------------------

def query_fingerprint(q_dims, q_vals, q_dense, *extra) -> str:
    """Content hash of one query (or query batch) for result caching.

    Hashes the raw bytes of the padded sparse query (dims + vals), the dense
    query, and any extra context (search params, index generation) — two
    requests collide only if every input byte agrees, so a cache keyed on
    this digest can never serve a stale or mismatched result.  Host-side
    numpy; meant to run once per request on arrays that are already on host.
    """
    h = hashlib.blake2b(digest_size=16)
    for a in (q_dims, q_vals, q_dense):
        a = np.ascontiguousarray(np.asarray(a))
        h.update(str((a.shape, a.dtype.str)).encode())
        h.update(a.tobytes())
    for e in extra:
        h.update(repr(e).encode())
    return h.hexdigest()


def release_index_arrays(arrays: IndexArrays, *, keep=()) -> int:
    """Donation hook for double-buffered index swaps (DESIGN.md §5).

    Deletes the device buffers of a RETIRED ``IndexArrays`` copy so its HBM
    is reclaimed immediately — the host-side analogue of jit buffer donation
    for a pytree that lives across dispatches rather than inside one.  Leaves
    that also appear in any pytree of ``keep`` (e.g. the replacement arrays
    sharing a codebook, or per-shard views sharing ``head_pos``) are skipped,
    as are non-jax leaves and buffers already deleted.  Returns the number of
    buffers deleted.  Callers must ensure no in-flight computation still
    reads ``arrays`` (QueryService refcounts generations for exactly this).
    """
    keep_ids = {id(leaf) for tree in keep for leaf in jax.tree.leaves(tree)}
    deleted = 0
    for leaf in jax.tree.leaves(arrays):
        if (isinstance(leaf, jax.Array) and id(leaf) not in keep_ids
                and not leaf.is_deleted()):
            leaf.delete()
            deleted += 1
    return deleted
