"""Cache sorting (paper Algorithm 1) and the cache-line cost model (Eq. 4 / Eq. 5).

The paper's observation: accumulator memory is moved in fixed-size blocks of B
slots (64-byte cache-lines on x86; VMEM tile rows on TPU — see DESIGN.md §2).
For every (dimension j, row-block b) pair, the block must be touched iff any of
its B datapoints is nonzero in dimension j.  Cache sorting finds a permutation
pi of datapoint order that clusters nonzeros of the most active dimensions into
contiguous runs, minimizing the number of touched blocks.

Algorithm 1 is equivalent to sorting the per-point activity indicator vectors
(dimensions ordered most→least active) in decreasing lexicographic order; we
implement it as the paper describes — recursive stable partitioning — with an
explicit work stack, O(N log N) average.
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp

__all__ = [
    "cache_sort",
    "expected_cost_unsorted",
    "expected_cost_sorted_bound",
    "measured_block_cost",
    "block_occupancy",
]


def _as_csc(x) -> sp.csc_matrix:
    if sp.issparse(x):
        return x.tocsc()
    return sp.csc_matrix(np.asarray(x))


def dimension_activity(x_sparse) -> np.ndarray:
    """nnz per dimension (column), the paper's ``nnz_j``."""
    xc = _as_csc(x_sparse)
    return np.diff(xc.indptr)


def cache_sort(x_sparse, max_dims: int | None = None, min_segment: int = 2) -> np.ndarray:
    """Paper Algorithm 1: returns a permutation ``pi`` of datapoint indices.

    ``x_sparse``: (N, d^S) scipy sparse (or dense ndarray) of the sparse component.
    ``max_dims``: partition on at most this many most-active dimensions.  Beyond
        ~log2(N) dimensions segments have length < 2 and partitioning is a no-op;
        the default covers that automatically via ``min_segment``.
    ``min_segment``: stop partitioning ranges shorter than this.

    Only CSC index structure is used (value magnitudes are irrelevant), matching
    the paper's 16-bytes-per-datapoint prefix-sorting implementation note.
    """
    xc = _as_csc(x_sparse)
    n, d = xc.shape
    nnz = np.diff(xc.indptr)
    # eta: dimensions sorted most→least active; ties broken by dim id for determinism.
    eta = np.lexsort((np.arange(d), -nnz))
    if max_dims is None:
        # Partitioning depth beyond ~log2(N)+constant can't split further.
        max_dims = min(d, max(2 * int(np.ceil(np.log2(max(n, 2)))) + 8, 16))
    eta = eta[: max_dims]
    eta = eta[nnz[eta] > 0]

    pi = np.arange(n, dtype=np.int64)
    # Explicit stack of (start, end, j) replacing the paper's recursion.
    stack = [(0, n, 0)]
    # Pre-extract row-index sets per partition dimension as boolean bitmaps.
    # Memory: len(eta) * N bits ~ fine for the N we build on one host shard.
    indicator = {}
    for j_rank, j in enumerate(eta):
        col = np.zeros(n, dtype=bool)
        col[xc.indices[xc.indptr[j]: xc.indptr[j + 1]]] = True
        indicator[j_rank] = col

    while stack:
        start, end, j = stack.pop()
        if end - start < min_segment or j >= len(eta):
            continue
        seg = pi[start:end]
        active = indicator[j][seg]
        n_active = int(active.sum())
        if n_active == 0 or n_active == end - start:
            # No split; recurse on the next dimension over the same range.
            stack.append((start, end, j + 1))
            continue
        # Stable partition: actives first (paper puts nonzero block contiguous).
        order = np.argsort(~active, kind="stable")
        pi[start:end] = seg[order]
        pivot = start + n_active
        stack.append((start, pivot, j + 1))
        stack.append((pivot, end, j + 1))
    return pi


# ---------------------------------------------------------------------------
# Cost model (paper §3.1 and §3.3)
# ---------------------------------------------------------------------------

def expected_cost_unsorted(p: np.ndarray, q: np.ndarray, n: int, b: int) -> float:
    """Eq. 4: E[C_unsort] = sum_j Q_j (1 - (1 - P_j)^B) N/B."""
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    return float(np.sum(q * (1.0 - (1.0 - p) ** b) * (n / b)))


def expected_cost_sorted_bound(p: np.ndarray, q: np.ndarray, n: int, b: int) -> float:
    """Eq. 5 upper bound on E[C_sort].

    After cache sorting, dimension j (1-indexed by activity rank) is split into
    at most 2^j contiguous blocks of nonzeros, each occupying ceil(P_j N / (2^j B))
    cache lines (worst case: no two runs share a line).  Once 2^j exceeds the
    number of nonzero lines, sorting gives no structure and the unsorted
    expectation applies.
    """
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    d = len(p)
    j = np.arange(1, d + 1, dtype=np.float64)
    two_j = np.minimum(2.0 ** np.minimum(j, 62), 2.0 ** 62)
    sorted_term = two_j * np.ceil(p * n / (two_j * b))
    unsorted_term = (1.0 - (1.0 - p) ** b) * (n / b)
    cost = np.where(p * n / b >= two_j, sorted_term, unsorted_term)
    return float(np.sum(q * np.minimum(cost, unsorted_term)))


def block_occupancy(x_sparse, b: int, pi: np.ndarray | None = None) -> np.ndarray:
    """(ceil(N/B), d) boolean: block i touches dimension j.

    This is the exact object the TPU tile-skipping kernel consumes (DESIGN.md §2)
    and the exact counter behind ``measured_block_cost``.
    """
    xc = _as_csc(x_sparse).tocoo()
    n, d = xc.shape
    rows = xc.row if pi is None else np.argsort(pi)[xc.row]
    nblocks = -(-n // b)
    occ = np.zeros((nblocks, d), dtype=bool)
    occ[rows // b, xc.col] = True
    return occ


def measured_block_cost(x_sparse, b: int, query_dims: np.ndarray,
                        pi: np.ndarray | None = None) -> int:
    """Exact number of (dimension, block) touches for one query's active dims.

    This is the paper's Cost(X^S) counter — the quantity cache sorting minimizes —
    measured on the actual layout rather than the i.i.d. model.
    """
    occ = block_occupancy(x_sparse, b, pi)
    return int(occ[:, np.asarray(query_dims)].sum())


def power_law_probs(d: int, alpha: float) -> np.ndarray:
    """P_j ∝ j^-alpha (paper §3.3), un-normalized as in Fig. 4 (P_1 = 1)."""
    j = np.arange(1, d + 1, dtype=np.float64)
    return np.minimum(1.0, j ** (-alpha))
