"""QueryService tour (DESIGN.md §5): micro-batching, result caching, shard
fan-out, and a non-blocking index refresh — on a synthetic hybrid index.

    PYTHONPATH=src python examples/serve_query_service.py
"""

import dataclasses
import time

import numpy as np

from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.core.sparse_index import sparse_queries_to_padded
from repro.data import make_hybrid_dataset
from repro.serve import QueryService


def main():
    print("building hybrid index...")
    ds = make_hybrid_dataset(num_points=8000, num_queries=32, d_sparse=10000,
                             d_dense=64, nnz_per_row=32, seed=0)
    params = HybridIndexParams(keep_top=64, head_dims=64, kmeans_iters=5)
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense, params)
    q_dims, q_vals = sparse_queries_to_padded(ds.q_sparse, idx.cols,
                                              nq_max=params.nq_max)
    q_dense = np.asarray(ds.q_dense, np.float32)

    # 4-shard fan-out service; ids mapped back to original row order
    svc = QueryService(idx.engine, h=10, buckets=(1, 8, 32),
                       cache_size=256, num_shards=4, id_map=idx.pi)

    # ragged request stream: every batch pads up to a bucket
    rng = np.random.default_rng(0)
    for q in (1, 3, 8, 20, 32):
        rows = rng.choice(32, q, replace=False)
        svc.search(q_dims[rows], q_vals[rows], q_dense[rows])
    jit = svc.jit_cache_info()
    print(f"ragged stream of 5 batch sizes -> padded shapes {jit.batch_shapes}"
          f" (bound {jit.bound})")

    # warm-cache repeat of an identical stream
    t0 = time.perf_counter()
    svc.search(q_dims, q_vals, q_dense)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    svc.search(q_dims, q_vals, q_dense)
    warm = time.perf_counter() - t0
    info = svc.cache_info()
    print(f"repeat stream: {cold * 1e3:.1f} ms cold -> {warm * 1e3:.2f} ms "
          f"warm (hit rate {info.hit_rate:.2f})")

    # async client API
    futs = [svc.submit(q_dims[i:i + 8], q_vals[i:i + 8], q_dense[i:i + 8])
            for i in (0, 8, 16, 24)]
    _ = [f.result() for f in futs]
    print("async submits:", svc.stats()["requests"], "queries served")

    # non-blocking refresh: rebuild with a different seed, swap, old buffers
    # are donated once idle; the same query now answers from the new index
    idx2 = HybridIndex.build(ds.x_sparse, ds.x_dense,
                             dataclasses.replace(params, seed=7))
    t0 = time.perf_counter()
    svc.refresh(idx2.engine, id_map=idx2.pi)
    print(f"refresh swap: {(time.perf_counter() - t0) * 1e3:.2f} ms "
          f"(old codes deleted: {idx.engine.arrays.codes.is_deleted()})")
    s, ids = svc.search(q_dims, q_vals, q_dense)
    assert s.shape == (32, 10)
    svc.close()
    print("final stats:", svc.stats())


if __name__ == "__main__":
    main()
