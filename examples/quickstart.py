"""Quickstart: build a hybrid index and search it.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import baselines as bl
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.data import make_hybrid_dataset


def main():
    print("generating a QuerySim-shaped synthetic hybrid dataset...")
    ds = make_hybrid_dataset(num_points=20000, num_queries=8,
                             d_sparse=50000, d_dense=64, nnz_per_row=64,
                             seed=0)

    print("building HybridIndex (cache-sort -> prune -> PQ -> residuals)...")
    idx = HybridIndex.build(ds.x_sparse, ds.x_dense,
                            HybridIndexParams(keep_top=128, head_dims=64))

    print("searching top-20 with 3-pass residual reordering...")
    result = idx.search(ds.q_sparse, ds.q_dense, h=20, alpha=20, beta=5)

    true_ids, true_scores = bl.exact_topk(ds.q_sparse, ds.q_dense,
                                          ds.x_sparse, ds.x_dense, 20)
    recall = bl.recall_at_h(result.ids, true_ids)
    print(f"recall@20 vs exact search: {recall:.3f}")
    print("query 0 top-5 ids:", result.ids[0, :5],
          "scores:", np.round(result.scores[0, :5], 3))
    assert recall > 0.8


if __name__ == "__main__":
    main()
