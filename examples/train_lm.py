"""End-to-end training driver: train a ~100M-parameter dense LM for a few
hundred steps on the deterministic synthetic stream, with checkpointing and
resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse

import jax

from repro.configs.base import ModelConfig, register
from repro.data.pipeline import DataConfig
from repro.models import Model
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig

# ~100M params: 12L, d=512, untied 32k vocab (2*32768*512 = 34M emb + 66M body)
CFG_100M = ModelConfig(
    name="demo-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=32768,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    model = Model(CFG_100M)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"model: {n_params / 1e6:.1f}M params")

    trainer = Trainer(
        model,
        AdamWConfig(lr_peak=3e-4, warmup_steps=20, decay_steps=args.steps),
        DataConfig(vocab_size=CFG_100M.vocab_size, seq_len=args.seq,
                   global_batch=args.batch),
        TrainerConfig(num_steps=args.steps, microbatches=2, ckpt_every=100,
                      ckpt_dir=args.ckpt, log_every=20),
    )
    params, opt, hist = trainer.run(jax.random.PRNGKey(0))
    losses = [h["loss"] for h in hist if not h["skipped"]]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    if trainer.straggler_steps:
        print(f"straggler steps flagged: {trainer.straggler_steps}")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
