"""Serving with the PQ-approximated hybrid LM head (the paper's technique
applied to large-vocab next-token retrieval).

    PYTHONPATH=src python examples/serve_pq_head.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serve import greedy_generate
from repro.serve.hybrid_head import HybridLMHead


def main():
    key = jax.random.PRNGKey(0)
    cfg = get_config("qwen2-7b-smoke")
    model = Model(cfg)
    params = model.init(key)

    prompt = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    print("greedy decode, exact full-vocab head...")
    exact = greedy_generate(model, params, prompt, 12, 64, use_pq_head=False,
                            penalty=1.0)
    print("greedy decode, PQ hybrid head (ADC + residual reorder)...")
    pq = greedy_generate(model, params, prompt, 12, 64, use_pq_head=True,
                         penalty=1.0)
    # Greedy decoding cascades: a single near-tie flip early in a sequence
    # desynchronizes everything after it, so sequence agreement understates
    # head accuracy.  The robust metric is FIRST-token agreement (no cascade).
    seq_agree = float((np.asarray(exact) == np.asarray(pq)).mean())
    first_agree = float((np.asarray(exact)[:, 0]
                         == np.asarray(pq)[:, 0]).mean())
    print(f"first-token agreement: {first_agree:.3f} "
          f"(sequence-level, cascade-affected: {seq_agree:.3f})")
    agree = first_agree

    # head-level cost accounting (what the technique buys at scale)
    head = HybridLMHead(cfg)
    hp = head.build(params["lm_head"])
    v, d = cfg.vocab_size, cfg.d_model
    exact_bytes = v * d * 4
    pq_bytes = hp.codes.shape[0] * hp.codes.shape[1]
    print(f"scan bytes/token: exact={exact_bytes:.2e} pq={pq_bytes:.2e} "
          f"({exact_bytes / pq_bytes:.0f}x reduction)")
    assert agree >= 0.8


if __name__ == "__main__":
    main()
