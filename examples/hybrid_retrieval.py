"""End-to-end hybrid retrieval: an LM produces dense embeddings, sparse
n-gram features provide the memorization channel, and the paper's
HybridIndex searches the combined space (the QuerySim pipeline of §7.1.2 in
miniature).

    PYTHONPATH=src python examples/hybrid_retrieval.py
"""

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.configs import get_config
from repro.core import baselines as bl
from repro.core.hybrid import HybridIndex, HybridIndexParams
from repro.models import Model


def lm_embed(model, params, tokens, weight: float = 0.5):
    """Mean-pooled final hidden state, L2-normalized and scaled.

    The paper fine-tunes the sparse/dense relative weight on ROC (§7.1.2);
    here the sparse features are L2-normalized so `weight` plays that role."""
    hidden, _ = model.forward(params, {"tokens": tokens}, return_hidden=True)
    e = np.asarray(hidden.mean(axis=1), np.float32)
    return weight * e / (np.linalg.norm(e, axis=1, keepdims=True) + 1e-9)


def ngram_features(docs, vocab: int, d_sparse: int = 30000):
    """Hashed unigram+bigram tf features (the paper's sparse pipeline)."""
    rows, cols, vals = [], [], []
    for i, doc in enumerate(docs):
        grams = list(doc) + [(int(a) * 31 + int(b)) % (1 << 30)
                             for a, b in zip(doc[:-1], doc[1:])]
        for g in grams:
            rows.append(i)
            cols.append(int(g) % d_sparse)
            vals.append(1.0)
    m = sp.csr_matrix((vals, (rows, cols)),
                      shape=(len(docs), d_sparse), dtype=np.float32)
    # tf -> l2-normalized
    norms = np.sqrt(m.multiply(m).sum(axis=1)).A.ravel() + 1e-9
    return sp.diags(1.0 / norms) @ m


def main():
    key = jax.random.PRNGKey(0)
    cfg = get_config("qwen2-7b-smoke")
    model = Model(cfg)
    params = model.init(key)

    # corpus: random token documents; queries: perturbed copies (planted)
    n_docs, doclen = 3000, 24
    docs = np.asarray(jax.random.randint(key, (n_docs, doclen), 0,
                                         cfg.vocab_size))
    q_src = np.random.default_rng(0).choice(n_docs, 8, replace=False)
    queries = docs[q_src].copy()
    queries[:, ::5] = (queries[:, ::5] + 7) % cfg.vocab_size  # perturb 20%

    print("embedding corpus with the LM (dense channel)...")
    x_dense = lm_embed(model, params, jnp.asarray(docs))
    q_dense = lm_embed(model, params, jnp.asarray(queries))
    print("hashing n-grams (sparse channel)...")
    x_sparse = ngram_features(docs, cfg.vocab_size)
    q_sparse = ngram_features(queries, cfg.vocab_size)

    print("building hybrid index + query service...")
    params = HybridIndexParams(keep_top=64, head_dims=64, kmeans_iters=5)
    idx = HybridIndex.build(x_sparse, x_dense, params)

    # serve through the batched QueryService (DESIGN.md §5): bucketed
    # micro-batching + LRU result cache, ids mapped back through pi
    from repro.core.sparse_index import sparse_queries_to_padded
    from repro.serve import QueryService
    svc = QueryService(idx.engine, h=10, alpha=20, beta=5,
                       cache_size=128, id_map=idx.pi)
    q_dims, q_vals = sparse_queries_to_padded(q_sparse, idx.cols,
                                              nq_max=params.nq_max)
    _, ids = svc.search(q_dims, q_vals, q_dense)
    _, ids_warm = svc.search(q_dims, q_vals, q_dense)   # served from cache
    assert np.array_equal(ids, ids_warm)
    info = svc.cache_info()
    print(f"service cache: {info.hits} hits / {info.misses} misses "
          f"(hit rate {info.hit_rate:.2f})")

    planted_found = np.mean([src in row for src, row in zip(q_src, ids)])
    true_ids, _ = bl.exact_topk(q_sparse, q_dense, x_sparse, x_dense, 10)
    recall = bl.recall_at_h(ids, true_ids)
    print(f"planted-source hit rate: {planted_found:.2f}")
    print(f"recall@10 vs exact hybrid search: {recall:.3f}")
    assert planted_found >= 0.7


if __name__ == "__main__":
    main()
